//! **Regionalized serving**: one gateway per region, federated over a
//! region topology with cross-gateway spill — now driven by a
//! conservative-time **sharded engine** that runs regions on worker
//! threads with byte-identical output at any shard count.
//!
//! The single-gateway stack assumed one cluster behind one front door;
//! this module runs one full [`Gateway`] (admission, DRR tenant queues,
//! batcher, locality router, coordinator, optional autoscaler) per
//! **region** of a [`RegionTopology`], and federates them:
//!
//! 1. **Conservative windows, one virtual clock** — every region is a
//!    [`RegionRunner`] owning its gateway, its slice of the inter-region
//!    mesh and its inbox of cross-region messages. The orchestrator
//!    advances all runners window by window: each window ends at
//!    `min(next exchange, next fault, earliest next event + lookahead)`
//!    where the lookahead is the smallest possible cross-region message
//!    latency (`SpillConfig::fixed_s + base_latency_s + min extra
//!    latency`). No message can arrive inside the window it was sent in,
//!    so runners are independent within a window — they execute inline
//!    (`shards == 1`, the sequential special case) or on a
//!    [`WorkerCrew`] (`--shards N`) with **byte-identical** results:
//!    same windows, same per-runner steps, same merged message order.
//! 2. **Federated pressure signal** — every `exchange_s` seconds each
//!    region publishes a [`RegionWindow`] (completions, sheds, window
//!    p95, live queue headroom); the table of peer windows is what spill
//!    decisions route on (deliberately a little stale — regions exchange
//!    signals, they do not share memory).
//! 3. **Cross-gateway spill** — overflow forwards to a peer advertising
//!    headroom instead of shedding: it pays the inter-region link cost
//!    on the region's row of the FIFO mesh
//!    ([`crate::net::NetModel::inter_region`]), travels as a
//!    [`RegionMsg`] over the shard lanes, and is merged into the
//!    destination inbox by the packed `(arrival time, sender, sender
//!    seq)` key. Forwards never re-spill; a forward that finds no room
//!    on delivery sheds at its *origin* when the timed shed-note makes
//!    it back over the same mesh latency.
//! 4. **Federated autoscaling** — each exchange tells a region's
//!    coordinator its own pressure and hands regions that *received*
//!    spill an expert-boost vector built from the spilled tasks'
//!    activation profiles.
//! 5. **Thin global view** — regions own disjoint clusters and ledgers;
//!    [`MultiGateway::global_view`] aggregates them for consistency
//!    checks.
//!
//! Chaos faults ride the same machinery: engine-level crashes/rejoins
//! are pre-installed and fire on the owning shard's own clock inside
//! `advance_to`; orchestrator-level faults (link degrade / partition /
//! restore, flash crowds) are barriers — windows never step past the
//! next fault's time, and the fault command goes to the owning runner
//! exactly at it. See `docs/PARALLEL.md` for the full determinism
//! argument.
//!
//! The canonical 3-region scenario ([`RegionsScenario`]) staggers each
//! region's diurnal peak by a third of the period; `regions_comparison`
//! runs it three ways (spill, isolated, single global gateway) and
//! `bench_file_json` serializes the deterministic comparison for
//! `BENCH_regions.json`. [`RegionsScenario::big`] is the 10×-larger
//! sharding showcase (12 regions × 84 servers) behind
//! `BENCH_parallel.json`.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use crate::cluster::RegionTopology;
use crate::config::{ClusterConfig, ModelConfig, TaskKind, WorkloadConfig};
use crate::coordinator::CoordinatorConfig;
use crate::net::NetModel;
use crate::obs::comms::{NUM_PURPOSES, OBS_SCHEMA_VERSION};
use crate::obs::{chrome, ObsConfig, TransferPurpose};
use crate::placement::uniform;
use crate::serve::statsbus::{RegionBus, RegionWindow};
use crate::serve::{
    ArrivalProfile, Gateway, GatewayConfig, GatewayReport,
};
use crate::trace::{Request, TaskProfile};
use crate::util::json::Json;
use crate::util::threadpool::WorkerCrew;
use crate::{Error, Result};

/// Peers whose published pressure exceeds this are not spill targets —
/// forwarding into a region that is itself shedding only moves the
/// failure around.
pub const SPILL_MAX_PRESSURE: f64 = 0.5;

/// Cross-gateway spill policy knobs.
#[derive(Debug, Clone)]
pub struct SpillConfig {
    /// Enable cross-gateway spill (`false` = isolated regions; the
    /// federation exchange still runs, so both arms of the comparison
    /// see identical pressure plumbing).
    pub enabled: bool,
    /// Inter-region link bandwidth for forwarded requests (bits/s).
    pub bandwidth_bps: f64,
    /// Base one-way latency of the inter-region mesh (the topology's
    /// per-pair extra latency is added on top).
    pub base_latency_s: f64,
    /// Fixed per-forward overhead (RPC + re-admission), link-occupying.
    pub fixed_s: f64,
    /// A peer must advertise at least this much admission headroom in
    /// the last exchanged window to be a spill target.
    pub min_residual: usize,
    /// High-watermark pre-spill: once the request's tenant has less than
    /// this fraction of its region-wide queue capacity left, arrivals
    /// forward *before* hitting the shed cliff (rejected requests still
    /// forward as the backstop). Pre-spilling keeps the saturated
    /// region's queues hovering at the watermark instead of pinned at
    /// the cap — which is what turns spill into a p95 win, not just a
    /// shed-rate win: without it the tail sits on the full-buffer
    /// sojourn plateau in both arms. 0 disables (rejection-only spill).
    pub prespill_frac: f64,
    /// Federation exchange period (seconds).
    pub exchange_s: f64,
}

impl Default for SpillConfig {
    fn default() -> Self {
        SpillConfig {
            enabled: true,
            bandwidth_bps: 200e6,
            base_latency_s: 0.002,
            fixed_s: 0.005,
            min_residual: 6,
            prespill_frac: 0.5,
            exchange_s: 15.0,
        }
    }
}

/// Everything one regional gateway runs over.
pub struct RegionShard {
    pub cluster: ClusterConfig,
    pub workload: WorkloadConfig,
    pub gateway_cfg: GatewayConfig,
    pub coord_cfg: CoordinatorConfig,
}

fn task_index(task: TaskKind) -> usize {
    TaskKind::all().iter().position(|&t| t == task).unwrap()
}

/// The cross-region recorder flow id: packed (sender region, per-sender
/// sequence). Identical at the forward and deliver ends regardless of
/// sharding, so trace flow arrows pair up byte-identically.
fn flow_id(src: usize, seq: u64) -> u32 {
    ((src << 24) as u32) | ((seq & 0xFF_FFFF) as u32)
}

/// One cross-shard message on the bounded lanes.
#[derive(Debug, Clone)]
struct RegionMsg {
    src: usize,
    dst: usize,
    /// Per-sender FIFO sequence (shared across payload kinds).
    seq: u64,
    arrive_s: f64,
    /// Link occupancy of the transfer (pre-arrival spill booking).
    dur_s: f64,
    payload: MsgPayload,
}

#[derive(Debug, Clone)]
enum MsgPayload {
    /// A spilled request riding the inter-region mesh.
    Forward(Request),
    /// Origin-bound notice that a forward found no room on delivery:
    /// the origin sheds it (tenant books + recorder) when the notice
    /// arrives, paying the reverse mesh latency. A zero-latency origin
    /// write would break both shard isolation and the lookahead bound.
    ShedNote { tenant: usize, server: usize },
}

/// Inbox entry ordered by the packed `(arrival time, sender region,
/// sender sequence)` key — a total, shard-invariant delivery order even
/// on exact time ties.
#[derive(Debug)]
struct InboxEntry {
    arrive_bits: u64,
    src: usize,
    seq: u64,
    msg: RegionMsg,
}

impl InboxEntry {
    fn key(&self) -> (u64, usize, u64) {
        (self.arrive_bits, self.src, self.seq)
    }
}

impl PartialEq for InboxEntry {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}

impl Eq for InboxEntry {}

impl PartialOrd for InboxEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for InboxEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key().cmp(&other.key())
    }
}

/// Exchange phase-1 payload: this region's published window plus the
/// drained per-(destination, task) spilled-request counts.
type ExchangePayload = (RegionWindow, Vec<Vec<u64>>);

/// Fault-window snapshot: cumulative (offered, shed, per-region
/// completion counts) at the instant a fault window opened.
type FaultSnap = (u64, u64, Vec<usize>);

/// Fault-window probe: one region's cumulative counters, plus SLO
/// violations over its completions since a snapshot index.
#[derive(Debug, Clone, Copy)]
struct ProbeReply {
    offered: u64,
    shed: u64,
    recs: usize,
    violations: u64,
}

/// One crash being tracked to recovery, runner-side. Timestamps are
/// recorded against the runner's own clock at its (shard-invariant)
/// step bottoms; the orchestrator folds them into [`FaultRecord`]s
/// after the runners are reassembled.
#[derive(Debug, Clone)]
struct CrashTrack {
    fault: usize,
    server: usize,
    t_crash: f64,
    seen_dead: bool,
    t_staged: Option<f64>,
    done: bool,
    t_done: f64,
}

/// Commands the orchestrator sends a runner (inline or over the crew
/// lanes). Every command returns a [`Reply`] carrying the runner's
/// refreshed work hint plus any cross-region messages it produced.
enum Cmd {
    /// Pure hint query (no side effects) — seeds the scheduler state.
    Hint,
    /// Advance through the window `(now, end]`: deliver handed-over
    /// messages, process every local event strictly before `end`, then
    /// park the engine exactly at `end`.
    RunWindow { end: f64, msgs: Vec<RegionMsg> },
    /// Fire the gateway interval tick if due at `t` (barrier ordering:
    /// faults → tick → exchange, matching the sequential step).
    Tick(f64),
    /// Exchange phase 1: publish this region's window (and drain the
    /// per-destination spilled-task counts for the boost).
    Exchange { t: f64 },
    /// Exchange phase 2: install the full window table and the
    /// coordinator's pressure + expert boost.
    ApplyExchange {
        windows: Vec<RegionWindow>,
        pressure: f64,
        boost: Vec<f64>,
    },
    /// Fault-window probe (see [`ProbeReply`]); `from` is this region's
    /// completion-count snapshot from the window being closed.
    FaultProbe { from: usize },
    /// Start tracking a pre-installed engine crash to recovery.
    Crash { fault: usize, server: usize, t: f64 },
    DegradeLink {
        dst: usize,
        bandwidth_scale: f64,
        extra_latency_s: f64,
    },
    Partition { dst: usize },
    RestoreLink { dst: usize },
    FlashCrowd { tenant: usize, count: usize, t: f64 },
    /// End of run: flush the engine.
    Finalize,
}

/// A runner's answer to one [`Cmd`].
struct Reply {
    /// Cross-region messages produced while handling the command; the
    /// orchestrator stages them for the destination's next window.
    outgoing: Vec<RegionMsg>,
    /// Anything left to do (gateway work or undelivered inbox)?
    has_work: bool,
    /// Earliest local event time (arrivals, batch deadlines, engine
    /// events, interval ticks, inbox arrivals); `INFINITY` when idle.
    next_t: f64,
    /// Exchange phase-1 payload.
    exchange: Option<ExchangePayload>,
    /// Fault-probe payload.
    probe: Option<ProbeReply>,
}

/// One region's complete serving stack plus its shard-local view of the
/// federation: the unit of parallelism. Within a window a runner touches
/// nothing outside itself, so regions execute concurrently and
/// byte-identically to the inline order.
struct RegionRunner {
    region: usize,
    nr: usize,
    gw: Gateway,
    bus: RegionBus,
    /// This region's private copy of the inter-region mesh. Only row
    /// `region` is ever booked (each region owns its *outgoing* links),
    /// so per-region byte totals re-sum to the sequential mesh exactly.
    net: NetModel,
    now: f64,
    /// Per-sender message sequence (forwards and shed-notes share it).
    seq: u64,
    token_bytes: f64,
    spill_cfg: SpillConfig,
    topology: RegionTopology,
    /// Latest exchanged window table — the federated signal spill
    /// routes on.
    windows: Vec<RegionWindow>,
    /// This region's outgoing links masked by a chaos partition.
    partitioned_row: Vec<bool>,
    inbox: BinaryHeap<Reverse<InboxEntry>>,
    outgoing: Vec<RegionMsg>,
    spilled_out: u64,
    spilled_in: u64,
    spill_shed: u64,
    /// Spilled-request counts per (destination region, task) since the
    /// last exchange (feeds the receiving region's expert boost).
    spill_tasks_to: Vec<Vec<u64>>,
    crash_tracks: Vec<CrashTrack>,
}

impl RegionRunner {
    fn fresh_task_counts(nr: usize) -> Vec<Vec<u64>> {
        vec![vec![0; TaskKind::all().len()]; nr]
    }

    fn next_seq(&mut self) -> u64 {
        let s = self.seq;
        self.seq += 1;
        s
    }

    fn push_inbox(&mut self, msg: RegionMsg) {
        self.inbox.push(Reverse(InboxEntry {
            arrive_bits: msg.arrive_s.to_bits(),
            src: msg.src,
            seq: msg.seq,
            msg,
        }));
    }

    fn inbox_peek_t(&self) -> Option<f64> {
        self.inbox
            .peek()
            .map(|Reverse(e)| f64::from_bits(e.arrive_bits))
    }

    /// Earliest local event time: arrivals / batch deadlines / engine
    /// events via the gateway, the interval tick, and inbox arrivals.
    fn hint_next_t(&self) -> f64 {
        let mut t = f64::INFINITY;
        if let Some(x) = self.gw.next_action_time(self.now) {
            t = t.min(x);
        }
        if self.gw.next_interval.is_finite() {
            t = t.min(self.gw.next_interval);
        }
        if let Some(a) = self.inbox_peek_t() {
            t = t.min(a);
        }
        t
    }

    fn reply(&mut self) -> Reply {
        Reply {
            outgoing: std::mem::take(&mut self.outgoing),
            has_work: self.gw.has_work() || !self.inbox.is_empty(),
            next_t: self.hint_next_t(),
            exchange: None,
            probe: None,
        }
    }

    /// The per-step tail every virtual time `t` gets, in the sequential
    /// step order: interval tick, message deliveries, arrival drain,
    /// batch dispatch, crash bookkeeping. (At barrier starts the tick
    /// already fired in the barrier's own Tick round, so it no-ops.)
    fn step_tail(&mut self, t: f64) {
        self.gw.tick_due(t);
        self.deliver_due(t);
        self.drain_arrivals(t);
        self.gw.dispatch_ready(t);
        self.poll_crash(t);
    }

    /// Advance through `(self.now, end]`: run the start tail (barrier
    /// effects land at window start), process every local event strictly
    /// before `end`, then park the engine exactly at `end`.
    fn run_window(&mut self, end: f64, msgs: Vec<RegionMsg>) -> Reply {
        for m in msgs {
            self.push_inbox(m);
        }
        let start = self.now;
        self.step_tail(start);
        loop {
            let t = self.hint_next_t();
            if t >= end {
                break;
            }
            self.gw.advance_to(t);
            self.now = t;
            self.step_tail(t);
        }
        self.gw.advance_to(end);
        self.now = end;
        self.poll_crash(end);
        self.reply()
    }

    /// Deliver every inbox message due by `now`, in `(arrival, sender,
    /// seq)` order. Forwards re-enter admission through the
    /// most-headroom server for their tenant; a forward that finds no
    /// room sends a timed shed-note back to its origin.
    fn deliver_due(&mut self, now: f64) {
        while let Some(Reverse(e)) = self.inbox.peek() {
            if f64::from_bits(e.arrive_bits) > now + 1e-9 {
                break;
            }
            let Reverse(e) = self.inbox.pop().expect("peeked inbox entry");
            let RegionMsg {
                src,
                seq,
                dur_s,
                payload,
                ..
            } = e.msg;
            match payload {
                MsgPayload::Forward(mut req) => {
                    let tenant = req.tenant;
                    let req_id = req.id as u64;
                    let arrival = req.arrival_s;
                    let home = req.server;
                    let mut entry = 0usize;
                    let mut best = 0usize;
                    for s in 0..self.gw.admission.num_servers() {
                        let res = self.gw.admission.tenant_residual(s, tenant);
                        if res > best {
                            best = res;
                            entry = s;
                        }
                    }
                    req.server = entry;
                    let obs = &mut self.gw.engine.obs;
                    obs.on_spill_deliver(flow_id(src, seq), src, self.region, now);
                    obs.note_prearrival_transfer(req_id, arrival, dur_s);
                    if self.gw.admit_forwarded(req, now) {
                        self.spilled_in += 1;
                    } else {
                        self.gw.engine.obs.clear_prearrival(req_id, arrival);
                        let back = self.shed_note_latency(src);
                        let nseq = self.next_seq();
                        self.outgoing.push(RegionMsg {
                            src: self.region,
                            dst: src,
                            seq: nseq,
                            arrive_s: now + back,
                            dur_s: back,
                            payload: MsgPayload::ShedNote {
                                tenant,
                                server: home,
                            },
                        });
                    }
                }
                MsgPayload::ShedNote { tenant, server } => {
                    self.spill_shed += 1;
                    self.gw.admission.record_shed_tenant(tenant);
                    self.gw.engine.obs.on_shed(tenant, server, now);
                }
            }
        }
    }

    /// Static one-way latency of a shed-note back to `dst` — the same
    /// fixed + base + pair-extra floor every mesh transfer pays, so it
    /// can never undercut the conservative lookahead.
    fn shed_note_latency(&self, dst: usize) -> f64 {
        self.spill_cfg.fixed_s
            + self.spill_cfg.base_latency_s
            + self.topology.extra_latency(self.region, dst)
    }

    fn drain_arrivals(&mut self, now: f64) {
        while let Some(req) = self.gw.pop_arrival_due(now) {
            self.route_arrival(req, now);
        }
    }

    /// Route one request arriving at this region — the shared
    /// pre-spill / admit / backstop-spill / shed path for scheduled
    /// arrivals and chaos flash-crowd injections alike.
    fn route_arrival(&mut self, req: Request, now: f64) {
        if self.spill_cfg.enabled && self.under_watermark(req.tenant) {
            if let Some(q) = self.spill_target(req.tenant) {
                // counted offered at home like any arrival, then
                // forwarded ahead of the shed cliff
                self.gw.offered += 1;
                self.forward(q, req, now);
                return;
            }
        }
        match self.gw.try_admit(req, now) {
            Ok(()) => {}
            Err(rej) => match self.spill_target(rej.tenant) {
                Some(q) => self.forward(q, rej, now),
                None => {
                    self.gw.admission.record_shed_tenant(rej.tenant);
                    self.gw.engine.obs.on_shed(rej.tenant, rej.server, now);
                }
            },
        }
    }

    /// Is `tenant`'s region-wide admission headroom below the pre-spill
    /// watermark?
    fn under_watermark(&self, tenant: usize) -> bool {
        if self.spill_cfg.prespill_frac <= 0.0 {
            return false;
        }
        let adm = &self.gw.admission;
        let n = adm.num_servers();
        let mut residual = 0usize;
        for s in 0..n {
            residual += adm.tenant_residual(s, tenant);
        }
        let cap = adm.tenant_cap(tenant) * n;
        (residual as f64) < self.spill_cfg.prespill_frac * cap as f64
    }

    /// Spill destination for this region's overflow of `tenant`: the
    /// peer advertising the most admission headroom in the last
    /// federation exchange, discounted by the inter-region latency to
    /// reach it. Peers under the headroom floor, without room in *this
    /// tenant's* own queues, or already pressured are skipped. `None` =
    /// shed at home.
    fn spill_target(&self, tenant: usize) -> Option<usize> {
        if !self.spill_cfg.enabled {
            return None;
        }
        let mut best: Option<(f64, usize)> = None;
        for q in 0..self.nr {
            if q == self.region || self.partitioned_row[q] {
                continue;
            }
            let w = &self.windows[q];
            if w.residual < self.spill_cfg.min_residual {
                continue;
            }
            if w.residual_by_tenant.get(tenant).copied().unwrap_or(0) == 0 {
                continue;
            }
            if w.pressure > SPILL_MAX_PRESSURE {
                continue;
            }
            let score = w.residual as f64
                / (1.0 + self.topology.extra_latency(self.region, q));
            if best.map(|(s, _)| score > s).unwrap_or(true) {
                best = Some((score, q));
            }
        }
        best.map(|(_, q)| q)
    }

    /// Forward a request to `dst`: book the prompt payload on this
    /// region's row of the mesh (FIFO contention) and emit the message;
    /// the orchestrator hands it to `dst` before any window that could
    /// contain its arrival.
    fn forward(&mut self, dst: usize, req: Request, now: f64) {
        self.spilled_out += 1;
        self.spill_tasks_to[dst][task_index(req.task)] += 1;
        let bytes = req.prompt_tokens as f64 * self.token_bytes;
        let at = self.net.book_transfer(
            self.region,
            dst,
            bytes,
            now,
            self.spill_cfg.fixed_s,
            TransferPurpose::RegionSpill,
        );
        let seq = self.next_seq();
        self.gw
            .engine
            .obs
            .on_spill_forward(flow_id(self.region, seq), self.region, dst, now, at);
        self.outgoing.push(RegionMsg {
            src: self.region,
            dst,
            seq,
            arrive_s: at,
            dur_s: at - now,
            payload: MsgPayload::Forward(req),
        });
    }

    /// Exchange phase 1: collect this region's window (emitting the
    /// `region_window` metrics row) and drain the per-destination
    /// spilled-task counts.
    fn exchange_window(&mut self, now: f64) -> ExchangePayload {
        let queued = self.gw.admission.total_queued();
        let residual = self.gw.admission.total_residual();
        let by_tenant: Vec<usize> = (0..self.gw.admission.num_tenants())
            .map(|tn| self.gw.admission.tenant_residual_total(tn))
            .collect();
        let w = self.bus.collect(
            &self.gw.engine.report,
            self.gw.admission.shed,
            queued,
            residual,
            by_tenant,
        );
        if self.gw.engine.obs.enabled() {
            // cumulative spill bytes this region pushed onto the
            // inter-region mesh (purpose-attributed at the mesh)
            let spill_bytes: f64 = (0..self.nr)
                .map(|q| self.net.link_bytes(self.region, q))
                .sum();
            let row = Json::from_pairs(vec![
                ("t_s", Json::Num(now)),
                ("kind", Json::Str("region_window".into())),
                ("schema", Json::Num(OBS_SCHEMA_VERSION as f64)),
                ("completed", Json::Num(w.completed as f64)),
                ("shed", Json::Num(w.shed as f64)),
                ("p95_s", Json::Num(w.p95_s)),
                ("queued", Json::Num(w.queued as f64)),
                ("residual", Json::Num(w.residual as f64)),
                ("pressure", Json::Num(w.pressure)),
                ("spilled_out", Json::Num(self.spilled_out as f64)),
                ("spilled_in", Json::Num(self.spilled_in as f64)),
                ("spill_shed", Json::Num(self.spill_shed as f64)),
                ("spill_bytes", Json::Num(spill_bytes)),
            ]);
            self.gw.engine.obs.push_metrics_row(row);
        }
        let drained = std::mem::replace(
            &mut self.spill_tasks_to,
            RegionRunner::fresh_task_counts(self.nr),
        );
        (w, drained)
    }

    /// Fault-window probe: cumulative counters plus SLO violations over
    /// completions since the `from` snapshot.
    fn probe(&self, from: usize) -> ProbeReply {
        let recs = &self.gw.engine.report.records;
        let violations = recs[from.min(recs.len())..]
            .iter()
            .filter(|x| x.latency_s > self.gw.cfg.slo_s)
            .count() as u64;
        ProbeReply {
            offered: self.gw.offered,
            shed: self.gw.admission.shed,
            recs: recs.len(),
            violations,
        }
    }

    /// Inject a chaos flash crowd: `count` deterministic requests for
    /// `tenant` (clamped to the region's tenant set) offered through the
    /// normal admission path — conserved like any arrival. Ids are
    /// minted from the gateway's own arrival id space so they never
    /// collide with scheduled arrivals.
    fn inject_flash_crowd(&mut self, tenant: usize, count: usize, now: f64) {
        let tenant = tenant.min(self.gw.admission.num_tenants().saturating_sub(1));
        let num_servers = self.gw.admission.num_servers();
        for i in 0..count {
            let id = self.gw.arrivals.mint_id();
            let req = Request {
                id,
                server: i % num_servers,
                arrival_s: now,
                prompt_tokens: 64,
                output_tokens: 16,
                task: TaskKind::Arithmetic,
                tenant,
            };
            self.route_arrival(req, now);
        }
    }

    /// Recovery bookkeeping per open crash, against this runner's own
    /// clock (times are step bottoms — shard-invariant).
    fn poll_crash(&mut self, now: f64) {
        for tr in &mut self.crash_tracks {
            if tr.done {
                continue;
            }
            if !tr.seen_dead {
                if self.gw.engine.server_dead(tr.server) {
                    tr.seen_dead = true;
                } else {
                    continue;
                }
            }
            if tr.t_staged.is_none()
                && !self.gw.coordinator.recover_pending.is_empty()
            {
                tr.t_staged = Some(now);
            }
            if self.gw.engine.placement.missing_experts().is_empty() {
                tr.done = true;
                tr.t_done = now;
            }
        }
    }
}

/// The command dispatcher — the one function both executors run, so the
/// inline path and the worker threads are the same code by construction.
fn handle(rr: &mut RegionRunner, cmd: Cmd) -> Reply {
    match cmd {
        Cmd::Hint => {}
        Cmd::RunWindow { end, msgs } => return rr.run_window(end, msgs),
        Cmd::Tick(t) => rr.gw.tick_due(t),
        Cmd::Exchange { t } => {
            let payload = rr.exchange_window(t);
            let mut reply = rr.reply();
            reply.exchange = Some(payload);
            return reply;
        }
        Cmd::ApplyExchange {
            windows,
            pressure,
            boost,
        } => {
            rr.windows = windows;
            rr.gw.coordinator.note_region_pressure(pressure, boost);
        }
        Cmd::FaultProbe { from } => {
            let probe = rr.probe(from);
            let mut reply = rr.reply();
            reply.probe = Some(probe);
            return reply;
        }
        Cmd::Crash { fault, server, t } => rr.crash_tracks.push(CrashTrack {
            fault,
            server,
            t_crash: t,
            seen_dead: false,
            t_staged: None,
            done: false,
            t_done: t,
        }),
        Cmd::DegradeLink {
            dst,
            bandwidth_scale,
            extra_latency_s,
        } => rr
            .net
            .degrade_link(rr.region, dst, bandwidth_scale, extra_latency_s),
        Cmd::Partition { dst } => rr.partitioned_row[dst] = true,
        Cmd::RestoreLink { dst } => {
            rr.partitioned_row[dst] = false;
            rr.net.restore_link(rr.region, dst);
        }
        Cmd::FlashCrowd { tenant, count, t } => {
            rr.inject_flash_crowd(tenant, count, t)
        }
        Cmd::Finalize => rr.gw.engine.finalize(),
    }
    rr.reply()
}

/// Where the runners execute: inline in region order (`shards == 1`,
/// the sequential special case) or on a [`WorkerCrew`]. Both paths call
/// [`handle`] per region in the same per-region order and collect
/// replies in region order, so they are byte-identical by construction.
enum Executor {
    Inline(Vec<RegionRunner>),
    Crew(WorkerCrew<RegionRunner, Cmd, Reply>),
}

impl Executor {
    fn broadcast<M: FnMut(usize) -> Cmd>(&mut self, mut mk: M) -> Vec<Reply> {
        match self {
            Executor::Inline(rs) => rs
                .iter_mut()
                .enumerate()
                .map(|(i, r)| handle(r, mk(i)))
                .collect(),
            Executor::Crew(c) => c.broadcast(mk),
        }
    }

    fn send_one(&mut self, i: usize, cmd: Cmd) -> Reply {
        match self {
            Executor::Inline(rs) => handle(&mut rs[i], cmd),
            Executor::Crew(c) => c.send_one(i, cmd),
        }
    }

    fn finish(self) -> Vec<RegionRunner> {
        match self {
            Executor::Inline(rs) => rs,
            Executor::Crew(c) => c.finish(),
        }
    }
}

/// The federation of regional gateways — and the conservative-time
/// orchestrator that drives its [`RegionRunner`]s window by window,
/// inline or sharded onto worker threads ([`MultiGateway::shards`]),
/// with byte-identical results either way.
pub struct MultiGateway {
    pub topology: RegionTopology,
    pub gateways: Vec<Gateway>,
    pub spill_cfg: SpillConfig,
    /// Worker threads to shard the regions onto (1 = run inline — the
    /// sequential special case). The window schedule never depends on
    /// this, so any shard count produces byte-identical output.
    pub shards: usize,
    /// Per-region copies of the FIFO inter-region mesh; region `r` only
    /// ever books row `r` (its outgoing links), so the per-region byte
    /// matrices re-assemble into the sequential mesh exactly.
    nets: Vec<NetModel>,
    /// activation-row bytes per prompt token (forward payload sizing)
    token_bytes: f64,
    /// per-task expert activation mass (flattened `l·E + e`), for the
    /// spill-derived autoscaler boost
    task_mass: Vec<Vec<f64>>,
    buses: Vec<RegionBus>,
    next_exchange: f64,
    // ---- accounting ------------------------------------------------
    /// forwards attempted, by origin region
    pub spilled_out: Vec<u64>,
    /// forwards admitted, by destination region
    pub spilled_in: Vec<u64>,
    /// forwards that found no room on delivery, by origin region
    pub spill_shed: Vec<u64>,
    /// federation exchanges run
    pub exchanges: u64,
    /// non-neutral spill boosts handed out, counted per receiving region
    /// per exchange (so this can exceed `exchanges` when several regions
    /// received spill in one window)
    pub boost_publishes: u64,
}

impl MultiGateway {
    /// Build one gateway per shard over `topology` (shard `i` = region
    /// `i`). Regions own disjoint clusters; the topology's job here is
    /// the inter-region link costs.
    pub fn new(
        model: &ModelConfig,
        shards: Vec<RegionShard>,
        topology: RegionTopology,
        spill_cfg: SpillConfig,
    ) -> MultiGateway {
        assert_eq!(
            topology.num_regions(),
            shards.len(),
            "one shard per region"
        );
        assert!(spill_cfg.exchange_s > 0.0, "exchange period must be > 0");
        let nr = shards.len();
        let mut gateways = Vec::with_capacity(nr);
        for shard in shards {
            let initial = uniform::place(model, &shard.cluster);
            gateways.push(Gateway::new(
                model,
                &shard.cluster,
                &shard.workload,
                initial,
                shard.gateway_cfg,
                shard.coord_cfg,
            ));
        }
        let nets = (0..nr)
            .map(|_| {
                NetModel::inter_region(
                    &topology,
                    spill_cfg.bandwidth_bps,
                    spill_cfg.base_latency_s,
                )
            })
            .collect();
        let task_mass: Vec<Vec<f64>> = TaskKind::all()
            .into_iter()
            .map(|t| {
                let prof = TaskProfile::build(t, model);
                let mut mass =
                    vec![0.0; model.num_layers * model.num_experts];
                for (l, dist) in prof.dist.iter().enumerate() {
                    for (e, &f) in dist.iter().enumerate() {
                        mass[l * model.num_experts + e] = f;
                    }
                }
                mass
            })
            .collect();
        let slo_s = gateways
            .first()
            .map(|g| g.cfg.slo_s)
            .unwrap_or(0.0);
        MultiGateway {
            topology,
            shards: 1,
            nets,
            token_bytes: model.token_bytes as f64,
            task_mass,
            buses: (0..nr).map(|_| RegionBus::new(slo_s)).collect(),
            next_exchange: 0.0,
            spilled_out: vec![0; nr],
            spilled_in: vec![0; nr],
            spill_shed: vec![0; nr],
            exchanges: 0,
            boost_publishes: 0,
            gateways,
            spill_cfg,
        }
    }

    /// The conservative lookahead: the smallest latency any cross-region
    /// message can pay (`fixed_s + base_latency_s + min pair extra`).
    /// A window ending at `earliest event + lookahead` therefore cannot
    /// contain the arrival of any message created inside it — the
    /// condition that makes regions independent within a window. Chaos
    /// link degradation only *adds* latency, so the static floor stays
    /// valid. `INFINITY` when no cross-region message can exist (spill
    /// disabled, or fewer than two regions).
    fn lookahead(&self) -> f64 {
        let nr = self.topology.num_regions();
        if !self.spill_cfg.enabled || nr <= 1 {
            return f64::INFINITY;
        }
        let mut extra = f64::INFINITY;
        for r in 0..nr {
            for q in 0..nr {
                if r != q {
                    extra = extra.min(self.topology.extra_latency(r, q));
                }
            }
        }
        let l = self.spill_cfg.fixed_s + self.spill_cfg.base_latency_s + extra;
        assert!(
            l > 1e-6,
            "conservative lookahead must exceed the time tolerance"
        );
        l
    }

    /// Drive every regional gateway (and the spill mesh) to completion
    /// on one virtual clock. Single-shot, like [`Gateway::run`].
    pub fn run(&mut self) -> RegionsReport {
        self.run_chaos(&crate::chaos::FaultSchedule::default()).regions
    }

    /// Drive every regional gateway to completion, injecting
    /// `schedule`'s faults at their exact virtual times, and measure
    /// recovery. The plain [`MultiGateway::run`] is this with an empty
    /// schedule.
    ///
    /// Engine-level faults (crashes, rejoins) are installed upfront into
    /// the owning region's event queue and fire at their exact virtual
    /// times inside the engine — on the owning shard's own clock;
    /// orchestrator-level faults (link degradation/partition/restore,
    /// flash crowds) are barriers: no window ever steps past the next
    /// pending fault, and the fault command goes to the owning runner
    /// exactly at it. Recovery is tracked per crash: *detection* ends at
    /// the scheduling boundary that staged the emergency re-covers,
    /// *re-copy* ends when every lost expert's coverage is restored.
    pub fn run_chaos(
        &mut self,
        schedule: &crate::chaos::FaultSchedule,
    ) -> crate::chaos::ChaosReport {
        use crate::chaos::{ChaosReport, FaultKind, FaultRecord};
        // stage replies into the scheduler state: refresh the region's
        // work hint, route produced messages to their destinations
        fn absorb(
            hints: &mut [(bool, f64)],
            staged: &mut [Vec<RegionMsg>],
            r: usize,
            rep: Reply,
        ) -> (Option<ExchangePayload>, Option<ProbeReply>) {
            hints[r] = (rep.has_work, rep.next_t);
            for m in rep.outgoing {
                staged[m.dst].push(m);
            }
            (rep.exchange, rep.probe)
        }
        let nr = self.gateways.len();
        for ev in &schedule.events {
            match ev.kind {
                FaultKind::ServerCrash { region, server } => self.gateways
                    [region]
                    .engine
                    .schedule_server_crash(ev.t_s, server),
                FaultKind::ServerRejoin { region, server } => self.gateways
                    [region]
                    .engine
                    .schedule_server_rejoin(ev.t_s, server),
                _ => {}
            }
        }
        let n = schedule.events.len();
        let mut records: Vec<FaultRecord> = schedule
            .events
            .iter()
            .map(|ev| FaultRecord {
                t_s: ev.t_s,
                label: ev.kind.label(),
                recovery_s: -1.0,
                detect_s: -1.0,
                recopy_s: -1.0,
                offered_during: 0,
                shed_during: 0,
                completed_during: 0,
                violations_during: 0,
            })
            .collect();
        let lookahead = self.lookahead();
        // hand each region's stack to its runner (reassembled at the end)
        let gateways = std::mem::take(&mut self.gateways);
        let buses = std::mem::take(&mut self.buses);
        let nets = std::mem::take(&mut self.nets);
        let mut runners = Vec::with_capacity(nr);
        for (r, ((gw, bus), net)) in
            gateways.into_iter().zip(buses).zip(nets).enumerate()
        {
            runners.push(RegionRunner {
                region: r,
                nr,
                gw,
                bus,
                net,
                now: 0.0,
                seq: 0,
                token_bytes: self.token_bytes,
                spill_cfg: self.spill_cfg.clone(),
                topology: self.topology.clone(),
                windows: vec![RegionWindow::default(); nr],
                partitioned_row: vec![false; nr],
                inbox: BinaryHeap::new(),
                outgoing: Vec::new(),
                spilled_out: 0,
                spilled_in: 0,
                spill_shed: 0,
                spill_tasks_to: RegionRunner::fresh_task_counts(nr),
                crash_tracks: Vec::new(),
            });
        }
        let workers = self.shards.clamp(1, nr.max(1));
        let mut exec = if workers <= 1 {
            Executor::Inline(runners)
        } else {
            Executor::Crew(WorkerCrew::new(runners, workers, handle))
        };
        let mut hints: Vec<(bool, f64)> = vec![(false, f64::INFINITY); nr];
        let mut staged: Vec<Vec<RegionMsg>> =
            (0..nr).map(|_| Vec::new()).collect();
        for (r, rep) in exec.broadcast(|_| Cmd::Hint).into_iter().enumerate()
        {
            absorb(&mut hints, &mut staged, r, rep);
        }
        // fault windows tile the run: each opens at its fault's instant
        // and closes at the next fault's (or the end of the run)
        let mut open: Option<(usize, FaultSnap)> = None;
        let mut fault_idx = 0usize;
        let mut start = 0.0f64;
        loop {
            let any_staged = staged.iter().any(|s| !s.is_empty());
            if fault_idx >= n && !any_staged && !hints.iter().any(|h| h.0) {
                break;
            }
            // earliest possible next event anywhere: region hints plus
            // staged (not yet handed over) message arrivals
            let mut t0 = f64::INFINITY;
            for h in &hints {
                t0 = t0.min(h.1);
            }
            for msgs in &staged {
                for m in msgs {
                    t0 = t0.min(m.arrive_s);
                }
            }
            // conservative window end: nothing created after t0 can
            // arrive before t0 + lookahead, and exchanges/faults are
            // hard barriers
            let mut end = self.next_exchange;
            if fault_idx < n {
                end = end.min(schedule.events[fault_idx].t_s);
            }
            end = end.min(t0 + lookahead);
            for (r, rep) in exec
                .broadcast(|r| Cmd::RunWindow {
                    end,
                    msgs: std::mem::take(&mut staged[r]),
                })
                .into_iter()
                .enumerate()
            {
                absorb(&mut hints, &mut staged, r, rep);
            }
            start = end;
            // ---- fault barrier -------------------------------------
            let mut fault_applied = false;
            while fault_idx < n
                && schedule.events[fault_idx].t_s <= start + 1e-9
            {
                // one probe round per fault: closes the previous window
                // and opens this one from the same snapshot
                let from: Vec<usize> = match &open {
                    Some((_, snap)) => snap.2.clone(),
                    None => vec![0; nr],
                };
                let mut probes: Vec<ProbeReply> = Vec::with_capacity(nr);
                for (r, rep) in exec
                    .broadcast(|r| Cmd::FaultProbe { from: from[r] })
                    .into_iter()
                    .enumerate()
                {
                    let (_, p) = absorb(&mut hints, &mut staged, r, rep);
                    probes.push(p.expect("fault probe reply"));
                }
                let off: u64 = probes.iter().map(|p| p.offered).sum();
                let shed: u64 = probes.iter().map(|p| p.shed).sum();
                let recs: Vec<usize> =
                    probes.iter().map(|p| p.recs).collect();
                if let Some((i, snap)) = open.take() {
                    let rec = &mut records[i];
                    rec.offered_during = off - snap.0;
                    rec.shed_during = shed - snap.1;
                    rec.completed_during = probes
                        .iter()
                        .enumerate()
                        .map(|(g, p)| (p.recs - snap.2[g]) as u64)
                        .sum();
                    rec.violations_during =
                        probes.iter().map(|p| p.violations).sum();
                }
                open = Some((fault_idx, (off, shed, recs)));
                let cmd = match schedule.events[fault_idx].kind {
                    FaultKind::ServerCrash { region, server } => Some((
                        region,
                        Cmd::Crash { fault: fault_idx, server, t: start },
                    )),
                    FaultKind::ServerRejoin { .. } => None,
                    FaultKind::LinkDegrade {
                        src,
                        dst,
                        bandwidth_scale,
                        extra_latency_s,
                    } => Some((
                        src,
                        Cmd::DegradeLink {
                            dst,
                            bandwidth_scale,
                            extra_latency_s,
                        },
                    )),
                    FaultKind::LinkPartition { src, dst } => {
                        Some((src, Cmd::Partition { dst }))
                    }
                    FaultKind::LinkRestore { src, dst } => {
                        Some((src, Cmd::RestoreLink { dst }))
                    }
                    FaultKind::FlashCrowd { region, tenant, count } => Some((
                        region,
                        Cmd::FlashCrowd { tenant, count, t: start },
                    )),
                };
                if let Some((r, cmd)) = cmd {
                    let rep = exec.send_one(r, cmd);
                    absorb(&mut hints, &mut staged, r, rep);
                }
                fault_applied = true;
                fault_idx += 1;
            }
            // ---- exchange barrier ----------------------------------
            let exchange_due = start + 1e-9 >= self.next_exchange;
            if fault_applied || exchange_due {
                // explicit tick round so the sequential step order at a
                // barrier (faults → tick → exchange → deliveries) holds;
                // the next window's start tail re-runs it as a no-op
                for (r, rep) in
                    exec.broadcast(|_| Cmd::Tick(start)).into_iter().enumerate()
                {
                    absorb(&mut hints, &mut staged, r, rep);
                }
            }
            if exchange_due {
                let mut payloads: Vec<ExchangePayload> =
                    Vec::with_capacity(nr);
                for (r, rep) in exec
                    .broadcast(|_| Cmd::Exchange { t: start })
                    .into_iter()
                    .enumerate()
                {
                    let (ex, _) = absorb(&mut hints, &mut staged, r, rep);
                    payloads.push(ex.expect("exchange payload"));
                }
                let windows: Vec<RegionWindow> =
                    payloads.iter().map(|(w, _)| w.clone()).collect();
                // aggregate the per-origin spilled-task counts by
                // destination (order-free u64 sums)
                let mut spill_tasks = RegionRunner::fresh_task_counts(nr);
                for (_, drained) in &payloads {
                    for (dst, counts) in drained.iter().enumerate() {
                        for (ti, &c) in counts.iter().enumerate() {
                            spill_tasks[dst][ti] += c;
                        }
                    }
                }
                let boosts: Vec<Vec<f64>> = (0..nr)
                    .map(|r| self.spill_boost(&spill_tasks[r]))
                    .collect();
                for b in &boosts {
                    if !b.is_empty() {
                        self.boost_publishes += 1;
                    }
                }
                self.exchanges += 1;
                self.next_exchange += self.spill_cfg.exchange_s;
                for (r, rep) in exec
                    .broadcast(|r| Cmd::ApplyExchange {
                        windows: windows.clone(),
                        pressure: windows[r].pressure,
                        boost: boosts[r].clone(),
                    })
                    .into_iter()
                    .enumerate()
                {
                    absorb(&mut hints, &mut staged, r, rep);
                }
            }
        }
        for (r, rep) in
            exec.broadcast(|_| Cmd::Finalize).into_iter().enumerate()
        {
            absorb(&mut hints, &mut staged, r, rep);
        }
        // close the last fault window over the finalized state (before
        // build_report drains the per-region completion records)
        if let Some((i, snap)) = open.take() {
            let mut probes: Vec<ProbeReply> = Vec::with_capacity(nr);
            for (r, rep) in exec
                .broadcast(|r| Cmd::FaultProbe { from: snap.2[r] })
                .into_iter()
                .enumerate()
            {
                let (_, p) = absorb(&mut hints, &mut staged, r, rep);
                probes.push(p.expect("fault probe reply"));
            }
            let rec = &mut records[i];
            rec.offered_during =
                probes.iter().map(|p| p.offered).sum::<u64>() - snap.0;
            rec.shed_during =
                probes.iter().map(|p| p.shed).sum::<u64>() - snap.1;
            rec.completed_during = probes
                .iter()
                .enumerate()
                .map(|(g, p)| (p.recs - snap.2[g]) as u64)
                .sum();
            rec.violations_during =
                probes.iter().map(|p| p.violations).sum();
        }
        // reassemble: runners come back in region order from both
        // executors (contiguous chunks, concatenated in order)
        self.spilled_out.clear();
        self.spilled_in.clear();
        self.spill_shed.clear();
        let mut crash_tracks: Vec<(usize, CrashTrack)> = Vec::new();
        for (r, rr) in exec.finish().into_iter().enumerate() {
            let RegionRunner {
                gw,
                bus,
                net,
                spilled_out,
                spilled_in,
                spill_shed,
                crash_tracks: tracks,
                ..
            } = rr;
            self.gateways.push(gw);
            self.buses.push(bus);
            self.nets.push(net);
            self.spilled_out.push(spilled_out);
            self.spilled_in.push(spilled_in);
            self.spill_shed.push(spill_shed);
            crash_tracks.extend(tracks.into_iter().map(|t| (r, t)));
        }
        for (_, tr) in &crash_tracks {
            if tr.done {
                let rec = &mut records[tr.fault];
                rec.recovery_s = tr.t_done - tr.t_crash;
                match tr.t_staged {
                    Some(ts) => {
                        rec.detect_s = ts - tr.t_crash;
                        rec.recopy_s = tr.t_done - ts;
                    }
                    None => {
                        // surviving replicas covered everything —
                        // nothing needed staging
                        rec.detect_s = 0.0;
                        rec.recopy_s = 0.0;
                    }
                }
            }
        }
        // build_report folds the final scale completions into each
        // coordinator (releasing tail-end reservations and counting the
        // recoveries that applied after the last boundary), so every
        // verdict below must read post-fold state
        let regions = self.build_report();
        // a crash whose dead window fell between window boundaries still
        // counts as recovered if the end state has full coverage
        for (r, tr) in &mut crash_tracks {
            if !tr.done {
                let gw = &self.gateways[*r];
                if gw.engine.placement.missing_experts().is_empty()
                    && gw.coordinator.recover_pending.is_empty()
                {
                    tr.done = true;
                    records[tr.fault].recovery_s = start - tr.t_crash;
                }
            }
        }
        let crashes: u64 =
            self.gateways.iter().map(|g| g.engine.crashes).sum();
        let recoveries: u64 = self
            .gateways
            .iter()
            .map(|g| g.coordinator.recoveries)
            .sum();
        let mut recovery_complete =
            crash_tracks.iter().all(|(_, t)| t.done);
        for gw in &self.gateways {
            recovery_complete &=
                gw.engine.placement.missing_experts().is_empty();
            recovery_complete &= gw.coordinator.recover_pending.is_empty();
        }
        let view = self.global_view();
        let ledger_balanced =
            view.validate().is_ok() && view.total_reserved() == 0;
        // exact conservation, in wide arithmetic so broken books report
        // as `false` instead of underflowing
        let mut conservation_exact = regions.offered as i128
            == regions.admitted as i128 + regions.shed as i128;
        let mut spilled_in_total: i128 = 0;
        for region in &regions.regions {
            let g = &region.gateway;
            conservation_exact &= g.offered as i128
                == (g.admitted as i128 - region.spilled_in as i128)
                    + (g.shed as i128 - region.spill_shed as i128)
                    + region.spilled_out as i128;
            conservation_exact &= g.forwarded_in == region.spilled_in;
            conservation_exact &=
                g.serve.records.len() as u64 == g.admitted;
            spilled_in_total += region.spilled_in as i128;
        }
        conservation_exact &= regions.spilled as i128
            == spilled_in_total + regions.spill_shed as i128;
        let mut max_recovery_s = -1.0f64;
        let mut any_crash = false;
        let mut all_recovered = true;
        for (i, ev) in schedule.events.iter().enumerate() {
            if matches!(ev.kind, FaultKind::ServerCrash { .. }) {
                any_crash = true;
                if records[i].recovery_s < 0.0 {
                    all_recovered = false;
                } else {
                    max_recovery_s =
                        max_recovery_s.max(records[i].recovery_s);
                }
            }
        }
        if !any_crash || !all_recovered {
            max_recovery_s = -1.0;
        }
        ChaosReport {
            regions,
            faults: records,
            crashes,
            recoveries,
            recovery_complete,
            conservation_exact,
            ledger_balanced,
            max_recovery_s,
        }
    }

    /// Expert boost for a region that received spill: `1 + share_t ·
    /// mass_t` summed over the spilled tasks, capped like the tenant
    /// boost — the receiving autoscaler prefers replicating exactly what
    /// the spill activates. Empty (neutral) when nothing spilled in.
    fn spill_boost(&self, counts: &[u64]) -> Vec<f64> {
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return Vec::new();
        }
        let n = self.task_mass.first().map(|m| m.len()).unwrap_or(0);
        let mut boost = vec![1.0; n];
        for (ti, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let share = c as f64 / total as f64;
            for (b, &m) in boost.iter_mut().zip(&self.task_mass[ti]) {
                *b += share * m;
            }
        }
        for b in &mut boost {
            *b = b.min(crate::serve::tenant::MAX_EXPERT_BOOST);
        }
        boost
    }

    /// Turn on the tracing layer in every regional gateway. Result-
    /// neutral, like [`Gateway::enable_obs`]: traced and untraced runs
    /// at one seed produce identical reports.
    pub fn enable_obs(&mut self, cfg: ObsConfig) {
        for gw in &mut self.gateways {
            gw.enable_obs(cfg.clone());
        }
    }

    /// One Chrome trace-event document over every region: region `r`'s
    /// tracks live under pid base `100·r` (named by region), and
    /// cross-region forwards appear as flow arrows between the origin's
    /// and destination's gateway tracks.
    pub fn trace_json(&self) -> Json {
        let parts: Vec<chrome::ExportPart> = self
            .gateways
            .iter()
            .enumerate()
            .map(|(r, gw)| chrome::ExportPart {
                label: self.topology.regions[r].name.clone(),
                pid_base: (r * 100) as u32,
                obs: &gw.engine.obs,
                server_names: gw
                    .engine
                    .cluster_cfg
                    .servers
                    .iter()
                    .map(|s| s.name.clone())
                    .collect(),
            })
            .collect();
        chrome::export(&parts)
    }

    /// The unified metrics-snapshot stream over every region: each
    /// region's rows tagged with its name, merged by the stable k-way
    /// `(time, within-region index, region)` key
    /// ([`crate::obs::merge_metrics_streams`]) — deterministic even on
    /// exact time ties, and independent of how regions were sharded.
    pub fn metrics_jsonl(&self) -> String {
        let streams: Vec<Vec<Json>> = self
            .gateways
            .iter()
            .enumerate()
            .map(|(r, gw)| {
                let name = &self.topology.regions[r].name;
                gw.engine
                    .obs
                    .metrics_rows
                    .iter()
                    .map(|row| {
                        let mut tagged = row.clone();
                        tagged.set("region", Json::Str(name.clone()));
                        tagged
                    })
                    .collect()
            })
            .collect();
        crate::obs::merge_metrics_streams(streams)
    }

    /// Flight-recorder dumps from every region, as one JSON document.
    pub fn flight_json(&self) -> Json {
        Json::from_pairs(vec![(
            "regions",
            Json::Arr(
                self.gateways
                    .iter()
                    .enumerate()
                    .map(|(r, gw)| {
                        Json::from_pairs(vec![
                            (
                                "region",
                                Json::Str(
                                    self.topology.regions[r].name.clone(),
                                ),
                            ),
                            ("flight", gw.engine.obs.flight_json()),
                        ])
                    })
                    .collect(),
            ),
        )])
    }

    /// The thin global coordination view: per-region ledger/placement
    /// memory accounting, aggregated for consistency checks.
    pub fn global_view(&self) -> GlobalView {
        let rows: Vec<RegionLedgerRow> = self
            .gateways
            .iter()
            .enumerate()
            .map(|(r, gw)| {
                let cluster = &gw.engine.cluster_cfg;
                let mut used = 0u64;
                let mut cap = 0u64;
                let mut reserved = 0u64;
                for (s, srv) in cluster.servers.iter().enumerate() {
                    for g in 0..srv.gpus.len() {
                        used += gw.engine.placement.mem_used(s, g);
                        cap += gw.coordinator.ledger.capacity(s, g);
                        reserved += gw.coordinator.ledger.reserved(s, g);
                    }
                }
                RegionLedgerRow {
                    name: self.topology.regions[r].name.clone(),
                    used,
                    reserved,
                    cap,
                }
            })
            .collect();
        GlobalView { rows }
    }

    /// Aggregate wall-clock-free engine work across every region
    /// (completed engine events) — the numerator of the sharded engine's
    /// aggregate events/s throughput metric.
    pub fn events_processed(&self) -> usize {
        self.gateways
            .iter()
            .map(|g| g.engine.events_processed())
            .sum()
    }

    fn build_report(&mut self) -> RegionsReport {
        let slo_s = self
            .gateways
            .first()
            .map(|g| g.cfg.slo_s)
            .unwrap_or(0.0);
        let mut regions = Vec::with_capacity(self.gateways.len());
        let mut all_lat: Vec<f64> = Vec::new();
        for (r, gw) in self.gateways.iter_mut().enumerate() {
            let rep = gw.build_report();
            let lat: Vec<f64> =
                rep.serve.records.iter().map(|x| x.latency_s).collect();
            all_lat.extend_from_slice(&lat);
            let p = crate::util::stats::percentiles(
                &lat,
                &[0.50, 0.95, 0.99],
            );
            regions.push(RegionSummary {
                name: self.topology.regions[r].name.clone(),
                spilled_out: self.spilled_out[r],
                spilled_in: self.spilled_in[r],
                spill_shed: self.spill_shed[r],
                p50_s: p[0],
                p95_s: p[1],
                p99_s: p[2],
                gateway: rep,
            });
        }
        let offered: u64 = regions.iter().map(|r| r.gateway.offered).sum();
        let admitted: u64 =
            regions.iter().map(|r| r.gateway.admitted).sum();
        let shed: u64 = regions.iter().map(|r| r.gateway.shed).sum();
        let completed: u64 = regions
            .iter()
            .map(|r| r.gateway.serve.records.len() as u64)
            .sum();
        let violations_completed: u64 = regions
            .iter()
            .map(|r| r.gateway.slo_violations_completed())
            .sum();
        let p = crate::util::stats::percentiles(
            &all_lat,
            &[0.50, 0.95, 0.99],
        );
        let obs_dropped: u64 =
            regions.iter().map(|r| r.gateway.obs_dropped).sum();
        let flight_dumps_dropped: u64 = regions
            .iter()
            .map(|r| r.gateway.flight_dumps_dropped)
            .sum();
        // each region only books its own row, so the per-region link
        // matrices concatenate (in region = src-major order) into
        // exactly the sequential mesh
        let mesh_links: Vec<(usize, usize, [f64; NUM_PURPOSES])> = self
            .nets
            .iter()
            .flat_map(|n| n.nonzero_links())
            .collect();
        let mesh_bytes: f64 =
            self.nets.iter().map(|n| n.total_bytes()).sum();
        RegionsReport {
            spill_enabled: self.spill_cfg.enabled,
            slo_s,
            spilled: self.spilled_out.iter().sum(),
            spill_shed: self.spill_shed.iter().sum(),
            exchanges: self.exchanges,
            boost_publishes: self.boost_publishes,
            offered,
            admitted,
            shed,
            completed,
            violations_completed,
            p50_s: p[0],
            p95_s: p[1],
            p99_s: p[2],
            mesh_links,
            mesh_bytes,
            obs_dropped,
            flight_dumps_dropped,
            regions,
        }
    }
}

/// The sharded-engine entry point: a [`MultiGateway`] pinned to a shard
/// count. Pure convenience — `shards == 1` *is* the sequential engine,
/// and any other count is byte-identical to it; this wrapper just makes
/// the parallel intent explicit at call sites (CLI, benches, tests).
pub struct ParallelMultiGateway(pub MultiGateway);

impl ParallelMultiGateway {
    /// Wrap `inner`, running its regions on `shards` worker threads
    /// (clamped to at least 1; counts above the region count are
    /// clamped down by the crew).
    pub fn new(mut inner: MultiGateway, shards: usize) -> Self {
        inner.shards = shards.max(1);
        ParallelMultiGateway(inner)
    }

    pub fn run(&mut self) -> RegionsReport {
        self.0.run()
    }

    pub fn run_chaos(
        &mut self,
        schedule: &crate::chaos::FaultSchedule,
    ) -> crate::chaos::ChaosReport {
        self.0.run_chaos(schedule)
    }
}

/// One region's slice of a multi-gateway run.
#[derive(Debug, Clone)]
pub struct RegionSummary {
    pub name: String,
    /// Forwards attempted from here (origin accounting).
    pub spilled_out: u64,
    /// Forwards admitted here (destination accounting).
    pub spilled_in: u64,
    /// Forwards from here that found no room on delivery (shed at
    /// origin).
    pub spill_shed: u64,
    /// Latency percentiles over requests *served in* this region
    /// (including spilled-in traffic).
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    /// The region's full gateway report (`offered` counts only this
    /// region's own arrivals; `admitted`/`shed` include spilled-in
    /// admissions / spill-sheds attributed here).
    pub gateway: GatewayReport,
}

/// Everything a multi-gateway run observed, aggregated.
#[derive(Debug, Clone)]
pub struct RegionsReport {
    pub spill_enabled: bool,
    pub slo_s: f64,
    pub regions: Vec<RegionSummary>,
    /// Σ forwards attempted.
    pub spilled: u64,
    /// Σ forwards that shed on delivery.
    pub spill_shed: u64,
    pub exchanges: u64,
    pub boost_publishes: u64,
    pub offered: u64,
    pub admitted: u64,
    pub shed: u64,
    pub completed: u64,
    pub violations_completed: u64,
    /// Latency percentiles over every completed request, all regions.
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    /// Inter-region mesh byte matrix: non-empty (src, dst) links with
    /// per-purpose bytes (spill forwards are the mesh's only traffic
    /// today, so only the `region_spill` slice is non-zero).
    pub mesh_links: Vec<(usize, usize, [f64; NUM_PURPOSES])>,
    /// Σ bytes over the inter-region mesh.
    pub mesh_bytes: f64,
    /// Σ spans dropped across every regional recorder (0 = complete).
    pub obs_dropped: u64,
    /// Σ flight dumps discarded across every regional recorder.
    pub flight_dumps_dropped: u64,
}

impl RegionsReport {
    /// Fraction of offered requests shed (anywhere, attributed to
    /// origin).
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.shed as f64 / self.offered as f64
        }
    }

    /// Fraction of offered requests forwarded across regions.
    pub fn spill_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.spilled as f64 / self.offered as f64
        }
    }

    /// SLO attainment over the offered load: completions within the SLO
    /// divided by everything offered (sheds count against, exactly like
    /// [`crate::serve::tenant::TenantReport::attainment`]).
    pub fn attainment(&self) -> f64 {
        if self.offered == 0 {
            1.0
        } else {
            (self.completed - self.violations_completed) as f64
                / self.offered as f64
        }
    }
}

/// One region's row of the global memory view.
#[derive(Debug, Clone)]
pub struct RegionLedgerRow {
    pub name: String,
    /// Bytes resident in the region's placement (active + draining).
    pub used: u64,
    /// Bytes reserved in the region's ledger (in-flight operations).
    pub reserved: u64,
    /// Region GPU capacity.
    pub cap: u64,
}

/// Thin global coordination view over the per-region ledgers — regions
/// own disjoint memory, so global consistency is "every region's
/// resident + reserved bytes fit its own capacity", checked in one
/// place.
#[derive(Debug, Clone)]
pub struct GlobalView {
    pub rows: Vec<RegionLedgerRow>,
}

impl GlobalView {
    pub fn total_reserved(&self) -> u64 {
        self.rows.iter().map(|r| r.reserved).sum()
    }

    pub fn validate(&self) -> Result<()> {
        for row in &self.rows {
            if row.used + row.reserved > row.cap {
                return Err(Error::Placement(format!(
                    "{}: resident {} + reserved {} exceeds capacity {}",
                    row.name, row.used, row.reserved, row.cap
                )));
            }
        }
        Ok(())
    }
}

/// The canonical regionalized scenario: `num_regions` independent
/// `servers_per_region`-server edge testbeds with **edge-grade
/// accelerators** (`gpu_scale` × an A100), each offering
/// `rps_per_region` of the bigbench mix under a diurnal profile whose
/// phase is staggered by `period_s / num_regions` per region. The
/// staggering keeps the cluster-wide offered load constant while every
/// region periodically runs past its own capacity — the regime where
/// cross-gateway spill converts sheds into served requests.
///
/// With the default `gpu_scale` the bottleneck is GPU compute (≈ 0.48 s
/// of GPU time per request over 3.75 effective GPUs ⇒ ≈ 7.8 req/s per
/// region), which placement changes cannot move — so "peak overloads,
/// trough idles, mean fits" holds by construction rather than by tuning:
/// the default mean of 5.5 req/s sits ~30 % under capacity while the
/// 2× diurnal peak sits ~40 % over it, and a fluid-model sensitivity
/// sweep (capacity mis-estimated by ±25 %) keeps both acceptance
/// deltas — spill cuts shed rate AND p95 — comfortably positive. The
/// p95 cut is structural: the pre-spill watermark
/// ([`SpillConfig::prespill_frac`]) keeps a saturated region's queues
/// hovering at half depth, below the full-buffer sojourn plateau the
/// isolated baseline's tail sits on.
#[derive(Debug, Clone)]
pub struct RegionsScenario {
    pub num_regions: usize,
    /// Servers in each region's cluster (the default 3 is the paper's
    /// edge testbed; [`RegionsScenario::big`] scales it up).
    pub servers_per_region: usize,
    /// Mean aggregate arrival rate per region (req/s).
    pub rps_per_region: f64,
    pub horizon_s: f64,
    /// Diurnal period; region `r` is phase-shifted by `r · period / R`.
    pub period_s: f64,
    pub amplitude: f64,
    /// Edge-accelerator compute as a fraction of an A100.
    pub gpu_scale: f64,
    pub queue_cap: usize,
    pub max_inflight: usize,
    /// Stats-bus / placement-refresh interval per region.
    pub interval_s: f64,
    pub slo_s: f64,
    pub spill: bool,
    /// Run the (region-aware) replica autoscaler in every region.
    pub autoscale: bool,
    /// Multi-tenant regions: every region serves this tenant set through
    /// its own per-(region, tenant) DRR queues; forwarded requests keep
    /// their tenant tag on arrival at the peer. `None` = single-tenant.
    /// Tenant profiles replace the diurnal profile, but each region's
    /// phase offset still applies to them.
    pub tenants: Option<crate::serve::TenantSet>,
    /// Extra one-way latency between any two regions.
    pub inter_latency_s: f64,
    /// Worker threads for the sharded engine (1 = inline; output is
    /// byte-identical at any value).
    pub shards: usize,
    pub seed: u64,
}

impl Default for RegionsScenario {
    fn default() -> Self {
        RegionsScenario {
            num_regions: 3,
            servers_per_region: 3,
            rps_per_region: 5.5,
            horizon_s: 480.0,
            period_s: 240.0,
            amplitude: 1.0,
            gpu_scale: 0.01,
            queue_cap: 8,
            max_inflight: 6,
            interval_s: 30.0,
            slo_s: 3.0,
            spill: true,
            autoscale: false,
            tenants: None,
            inter_latency_s: 0.03,
            shards: 1,
            seed: 0,
        }
    }
}

impl RegionsScenario {
    /// The 10×-larger sharding showcase behind `BENCH_parallel.json`:
    /// 12 regions × 84 servers = 1008 servers, offered-load-per-server
    /// held at the canonical scenario's operating point, over a short
    /// horizon (this is a throughput benchmark, not an SLO study).
    pub fn big(seed: u64) -> RegionsScenario {
        RegionsScenario {
            num_regions: 12,
            servers_per_region: 84,
            // the canonical 5.5 req/s over 3 servers, scaled to 84
            rps_per_region: 154.0,
            horizon_s: 60.0,
            seed,
            ..RegionsScenario::default()
        }
    }

    /// The model every region serves (trimmed Mixtral, like the other
    /// serving harnesses).
    pub fn model(&self) -> ModelConfig {
        let mut m = ModelConfig::mixtral_8x7b_sim();
        m.num_layers = 4;
        m
    }

    /// One region's cluster: the paper's edge testbed pattern at
    /// `servers_per_region` servers, with compute scaled down to
    /// edge-grade accelerators.
    fn region_cluster(&self, model: &ModelConfig) -> ClusterConfig {
        let mut c = ClusterConfig::edge_testbed_n_for(
            model,
            self.servers_per_region,
        );
        for s in &mut c.servers {
            for g in &mut s.gpus {
                g.flops *= self.gpu_scale.max(1e-4);
            }
        }
        c
    }

    /// Region `r`'s phase offset on the diurnal clock.
    pub fn phase(&self, region: usize) -> f64 {
        region as f64 * self.period_s / self.num_regions as f64
    }

    fn profile(&self) -> ArrivalProfile {
        ArrivalProfile::Diurnal {
            amplitude: self.amplitude,
            period_s: self.period_s,
        }
    }

    fn autoscale_cfg(&self) -> Option<crate::autoscale::AutoscaleConfig> {
        self.autoscale
            .then(crate::autoscale::AutoscaleConfig::default)
    }

    /// The topology: `num_regions` regions of `servers_per_region`
    /// servers each, every cross-region pair at `inter_latency_s` / half
    /// bandwidth.
    pub fn topology(&self) -> RegionTopology {
        RegionTopology::contiguous(
            &vec![self.servers_per_region; self.num_regions],
            self.inter_latency_s,
            0.5,
        )
    }

    /// Build the multi-gateway system (spill per `self.spill`, sharded
    /// onto `self.shards` worker threads).
    pub fn build(&self) -> MultiGateway {
        let model = self.model();
        let mut shards = Vec::with_capacity(self.num_regions);
        for r in 0..self.num_regions {
            let cluster = self.region_cluster(&model);
            // mean aggregate rate spread evenly over the streams
            let workload = WorkloadConfig::bigbench_n(
                cluster.num_servers() as f64 / self.rps_per_region,
                cluster.num_servers(),
            );
            let phase = self.phase(r);
            shards.push(RegionShard {
                gateway_cfg: GatewayConfig {
                    horizon_s: self.horizon_s,
                    profile: self.profile(),
                    queue_cap: self.queue_cap,
                    max_inflight: self.max_inflight,
                    slo_s: self.slo_s,
                    tenants: self.tenants.clone(),
                    stream_phases: Some(vec![
                        phase;
                        cluster.num_servers()
                    ]),
                    // region seeds decorrelate the arrival streams
                    seed: self.seed + 1000 * r as u64,
                    ..GatewayConfig::default()
                },
                coord_cfg: CoordinatorConfig {
                    interval_s: self.interval_s,
                    seed: self.seed + 1000 * r as u64,
                    autoscale: self.autoscale_cfg(),
                    ..CoordinatorConfig::default()
                },
                cluster,
                workload,
            });
        }
        let spill_cfg = SpillConfig {
            enabled: self.spill,
            ..SpillConfig::default()
        };
        let mut multi =
            MultiGateway::new(&model, shards, self.topology(), spill_cfg);
        multi.shards = self.shards;
        multi
    }

    /// The single-global-gateway baseline: one gateway over every
    /// region's servers merged into one cluster, with the region
    /// topology pricing its network (cross-region remote expert calls
    /// pay the inter-region cost inside the engine) and the same
    /// per-server diurnal phases. No spill concept — its admission
    /// preference walk already spans all servers.
    pub fn build_global(&self) -> Gateway {
        let model = self.model();
        let mut servers = Vec::new();
        let mut streams = Vec::new();
        let mut phases = Vec::new();
        for r in 0..self.num_regions {
            let shard = self.region_cluster(&model);
            let workload = WorkloadConfig::bigbench_n(
                shard.num_servers() as f64 / self.rps_per_region,
                shard.num_servers(),
            );
            for (i, s) in shard.servers.into_iter().enumerate() {
                let mut s = s;
                s.name = format!("r{r}-{}", s.name);
                servers.push(s);
                streams.push(workload.streams[i].clone());
                phases.push(self.phase(r));
            }
        }
        let base = self.region_cluster(&model);
        let merged = ClusterConfig {
            name: format!("regions-{}-merged", self.num_regions),
            servers,
            bandwidth_bps: base.bandwidth_bps,
            rtt_s: base.rtt_s,
        };
        let workload = WorkloadConfig {
            name: "regions-merged".into(),
            streams,
        };
        let initial = uniform::place(&model, &merged);
        Gateway::new(
            &model,
            &merged,
            &workload,
            initial,
            GatewayConfig {
                horizon_s: self.horizon_s,
                profile: self.profile(),
                queue_cap: self.queue_cap,
                max_inflight: self.max_inflight,
                slo_s: self.slo_s,
                tenants: self.tenants.clone(),
                stream_phases: Some(phases),
                topology: Some(self.topology()),
                seed: self.seed,
                ..GatewayConfig::default()
            },
            CoordinatorConfig {
                interval_s: self.interval_s,
                seed: self.seed,
                autoscale: self.autoscale_cfg(),
                ..CoordinatorConfig::default()
            },
        )
    }
}

/// The canonical three-way comparison behind the `regions` CLI, the
/// acceptance criterion and `BENCH_regions.json`: the default
/// [`RegionsScenario`] with spill, without spill (isolated regions),
/// and as one global gateway. Deterministic per (seed, horizon).
pub fn regions_comparison(
    seed: u64,
    horizon_s: f64,
) -> (RegionsReport, RegionsReport, GatewayReport) {
    let scenario = RegionsScenario {
        seed,
        horizon_s,
        ..RegionsScenario::default()
    };
    let spill = scenario.build().run();
    let isolated = RegionsScenario {
        spill: false,
        ..scenario.clone()
    }
    .build()
    .run();
    let global = scenario.build_global().run();
    (spill, isolated, global)
}

/// Deterministic metrics for `BENCH_regions.json`: per-region and
/// aggregate serving outcomes for all three arms, plus the spill-vs-
/// isolated deltas the CI guard checks. No wall-clock quantities — the
/// same (seed, horizon) serializes byte-identically across runs.
pub fn comparison_metrics(
    spill: &RegionsReport,
    isolated: &RegionsReport,
    global: &GatewayReport,
) -> Json {
    let mut j = Json::obj();
    for (mode, rep) in [("spill", spill), ("isolated", isolated)] {
        j.set(&format!("{mode}_offered"), Json::Num(rep.offered as f64));
        j.set(&format!("{mode}_shed"), Json::Num(rep.shed as f64));
        j.set(&format!("{mode}_spilled"), Json::Num(rep.spilled as f64));
        j.set(&format!("{mode}_shed_rate"), Json::Num(rep.shed_rate()));
        j.set(&format!("{mode}_spill_rate"), Json::Num(rep.spill_rate()));
        j.set(&format!("{mode}_p50_s"), Json::Num(rep.p50_s));
        j.set(&format!("{mode}_p95_s"), Json::Num(rep.p95_s));
        j.set(&format!("{mode}_p99_s"), Json::Num(rep.p99_s));
        j.set(
            &format!("{mode}_slo_attainment"),
            Json::Num(rep.attainment()),
        );
        for region in &rep.regions {
            let base = format!("{mode}_{}", region.name);
            j.set(
                &format!("{base}_offered"),
                Json::Num(region.gateway.offered as f64),
            );
            j.set(
                &format!("{base}_shed"),
                Json::Num(region.gateway.shed as f64),
            );
            j.set(
                &format!("{base}_spilled_out"),
                Json::Num(region.spilled_out as f64),
            );
            j.set(
                &format!("{base}_spilled_in"),
                Json::Num(region.spilled_in as f64),
            );
            j.set(&format!("{base}_p95_s"), Json::Num(region.p95_s));
        }
    }
    j.set("global_offered", Json::Num(global.offered as f64));
    j.set("global_shed", Json::Num(global.shed as f64));
    j.set("global_p95_s", Json::Num(global.latency_percentile(0.95)));
    j.set("global_p99_s", Json::Num(global.latency_percentile(0.99)));
    j.set(
        "spill_p95_improvement_s",
        Json::Num(isolated.p95_s - spill.p95_s),
    );
    j.set(
        "spill_shed_rate_reduction",
        Json::Num(isolated.shed_rate() - spill.shed_rate()),
    );
    j.set("spill_mesh_bytes", Json::Num(spill.mesh_bytes));
    j.set(
        "isolated_mesh_bytes",
        Json::Num(isolated.mesh_bytes),
    );
    j
}

/// The complete `BENCH_regions.json` document (no wall-clock block, so
/// the file is byte-identical across runs at the same seed — the replay
/// regression in `tests/region_properties.rs` locks exactly this).
pub fn bench_file_json(
    spill: &RegionsReport,
    isolated: &RegionsReport,
    global: &GatewayReport,
) -> Json {
    Json::from_pairs(vec![
        ("suite", Json::Str("regions".into())),
        ("metrics", comparison_metrics(spill, isolated, global)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskKind;
    use crate::serve::TenantSet;

    #[test]
    fn forwarded_requests_respect_receiving_drr_weights() {
        // Spill drops a forward into the receiving region's
        // per-(region, tenant) DRR queues under its own tenant tag — so
        // a backlog of forwarded requests dequeues by the receiving
        // region's weights (pair preset: 4:1).
        let mut m = ModelConfig::mixtral_8x7b_sim();
        m.num_layers = 4;
        let c = ClusterConfig::edge_testbed_3_for(&m);
        let w = WorkloadConfig::bigbench(10.0);
        let mut gw = Gateway::new(
            &m,
            &c,
            &w,
            uniform::place(&m, &c),
            GatewayConfig {
                tenants: Some(TenantSet::pair()),
                locality_routing: false,
                seed: 3,
                ..GatewayConfig::default()
            },
            CoordinatorConfig::default(),
        );
        for i in 0..20 {
            let req = Request {
                id: i,
                server: 0,
                arrival_s: 0.0,
                prompt_tokens: 16,
                output_tokens: 4,
                task: TaskKind::Arithmetic,
                tenant: i % 2,
            };
            assert!(gw.admit_forwarded(req, 0.0), "forward {i} must land");
        }
        assert_eq!(gw.forwarded_in, 20);
        assert_eq!(gw.offered, 0, "forwards are not locally offered");
        let popped = gw.admission.pop(0, 10);
        let t0 = popped.iter().filter(|q| q.req.tenant == 0).count();
        assert_eq!(
            (t0, popped.len() - t0),
            (8, 2),
            "10 pops at 4:1 weights dequeue 8:2"
        );
    }

    #[test]
    fn spill_moves_load_and_keeps_books_straight() {
        // A short canonical run with spill + autoscalers: forwards
        // happen, every counter reconciles, the federated boost reaches
        // the receiving coordinators, and the global ledger view stays
        // consistent.
        let scenario = RegionsScenario {
            horizon_s: 200.0,
            autoscale: true,
            seed: 5,
            ..RegionsScenario::default()
        };
        let mut multi = scenario.build();
        let report = multi.run();
        assert!(report.spill_enabled);
        assert!(report.offered > 0);
        assert!(report.spilled > 0, "staggered peaks must spill");
        assert!(report.exchanges >= 2);
        assert!(
            multi.boost_publishes > 0,
            "spilled-in traffic must publish an expert boost"
        );
        // per-region and global conservation (the property suite in
        // tests/region_properties.rs re-checks this through the public
        // API; this is the in-tree smoke)
        for region in &report.regions {
            let g = &region.gateway;
            assert_eq!(
                g.offered,
                (g.admitted - region.spilled_in)
                    + (g.shed - region.spill_shed)
                    + region.spilled_out,
                "{} books must balance",
                region.name
            );
            assert_eq!(g.forwarded_in, region.spilled_in);
            assert_eq!(g.serve.records.len() as u64, g.admitted);
        }
        assert_eq!(report.offered, report.admitted + report.shed);
        let spilled_in: u64 =
            report.regions.iter().map(|r| r.spilled_in).sum();
        assert_eq!(report.spilled, spilled_in + report.spill_shed);
        multi.global_view().validate().unwrap();
    }

    #[test]
    fn sharded_run_is_byte_identical_to_inline() {
        // The tentpole invariant, in-tree: the same scenario run inline
        // and on 2 worker shards serializes identically (the full
        // report, down to every float). tests/parallel_determinism.rs
        // sweeps seeds × shard counts × chaos through the public API.
        let scenario = RegionsScenario {
            horizon_s: 120.0,
            seed: 9,
            ..RegionsScenario::default()
        };
        let seq = scenario.build().run();
        let par = ParallelMultiGateway::new(scenario.build(), 2).run();
        assert_eq!(
            format!("{seq:?}"),
            format!("{par:?}"),
            "2-shard run must be byte-identical to inline"
        );
    }

    #[test]
    fn multi_tenant_regions_spill_under_tenant_tags() {
        // per-(region, tenant) DRR queues end to end: every region runs
        // the bursty pair preset; the batch tenant's flash crowds (40 s of
        // every 120 s, staggered 80 s per region so exactly one region
        // bursts at a time) overflow and spill, forwards keep their
        // tenant tag, and the per-tenant books still balance per region.
        let scenario = RegionsScenario {
            horizon_s: 150.0,
            tenants: Some(TenantSet::pair()),
            seed: 13,
            ..RegionsScenario::default()
        };
        let report = scenario.build().run();
        assert!(report.offered > 0);
        assert!(
            report.spilled > 0,
            "staggered batch bursts must overflow into peers"
        );
        assert_eq!(report.offered, report.admitted + report.shed);
        for region in &report.regions {
            let g = &region.gateway;
            assert_eq!(g.tenants.len(), 2, "{}", region.name);
            assert_eq!(
                g.offered,
                (g.admitted - region.spilled_in)
                    + (g.shed - region.spill_shed)
                    + region.spilled_out,
                "{} books must balance",
                region.name
            );
            // the per-tenant slices cover every admission and shed that
            // happened at this region's queues, forwarded traffic
            // included — spill lands under real tenant tags
            let adm: u64 = g.tenants.iter().map(|t| t.admitted).sum();
            let shed: u64 = g.tenants.iter().map(|t| t.shed).sum();
            assert_eq!(adm, g.admitted, "{}", region.name);
            assert_eq!(shed, g.shed, "{}", region.name);
        }
    }

    #[test]
    fn isolated_regions_never_spill() {
        let scenario = RegionsScenario {
            horizon_s: 120.0,
            spill: false,
            seed: 7,
            ..RegionsScenario::default()
        };
        let report = scenario.build().run();
        assert!(!report.spill_enabled);
        assert_eq!(report.spilled, 0);
        assert_eq!(report.spill_rate(), 0.0);
        assert_eq!(report.offered, report.admitted + report.shed);
        for region in &report.regions {
            assert_eq!(region.spilled_in, 0);
            assert_eq!(region.gateway.forwarded_in, 0);
        }
    }

    #[test]
    fn global_baseline_builds_and_serves() {
        let scenario = RegionsScenario {
            horizon_s: 90.0,
            seed: 11,
            ..RegionsScenario::default()
        };
        let mut gw = scenario.build_global();
        let report = gw.run();
        assert!(report.offered > 0);
        assert_eq!(report.offered, report.admitted + report.shed);
        assert_eq!(report.serve.records.len() as u64, report.admitted);
    }
}
