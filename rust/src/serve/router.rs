//! Locality-aware request routing — the paper's input-locality insight
//! applied *online*.
//!
//! DanceMoE's placement concentrates each task's hot experts near the
//! server whose stream activates them (§III-B); the router closes the loop
//! from the other side: score every server by the activation mass of the
//! request's task profile it hosts under the *current* placement, and send
//! the request to the best-scoring server. Under backpressure the router
//! spills down its preference list instead of shedding outright. Scores
//! are precomputed per (task, server) and rebuilt after migrations.

use crate::config::{ModelConfig, TaskKind};
use crate::placement::Placement;
use crate::trace::TaskProfile;

/// Activation mass of `profile` hosted locally by `server` under `p`:
/// `Σ_l Σ_e profile[l][e] · 1[server holds (l, e)]`. Ranges over
/// `[0, num_layers]` (each layer's distribution sums to 1).
pub fn hosted_mass(
    profile: &TaskProfile,
    p: &Placement,
    server: usize,
) -> f64 {
    let mut acc = 0.0;
    for (l, dist) in profile.dist.iter().enumerate() {
        for (e, &f) in dist.iter().enumerate() {
            if f > 0.0 && p.server_has(server, l, e) {
                acc += f;
            }
        }
    }
    acc
}

/// Precomputed per-(task, server) locality scores and preference orders.
#[derive(Debug, Clone)]
pub struct LocalityRouter {
    profiles: Vec<TaskProfile>,
    /// `scores[task][server]` — hosted activation mass.
    scores: Vec<Vec<f64>>,
    /// `pref[task][home]` — servers in descending preference order,
    /// precomputed so the per-arrival hot path is allocation-free.
    pref: Vec<Vec<Vec<usize>>>,
    num_servers: usize,
}

impl LocalityRouter {
    /// Build the router against an initial placement. Profiles are the
    /// deterministic task profiles of the model (the same tables the
    /// engine's gate samples from).
    pub fn new(model: &ModelConfig, p: &Placement) -> LocalityRouter {
        let mut r = LocalityRouter {
            profiles: TaskProfile::build_all(model),
            scores: Vec::new(),
            pref: Vec::new(),
            num_servers: p.num_servers,
        };
        r.rebuild(p);
        r
    }

    /// Recompute the score table and preference permutations against a
    /// (possibly migrated) placement.
    pub fn rebuild(&mut self, p: &Placement) {
        self.scores = self
            .profiles
            .iter()
            .map(|prof| {
                (0..self.num_servers)
                    .map(|n| hosted_mass(prof, p, n))
                    .collect()
            })
            .collect();
        self.pref = self
            .scores
            .iter()
            .map(|row| {
                (0..self.num_servers)
                    .map(|home| {
                        let mut idx: Vec<usize> =
                            (0..self.num_servers).collect();
                        idx.sort_by(|&a, &b| {
                            row[b]
                                .partial_cmp(&row[a])
                                .unwrap()
                                .then_with(|| {
                                    (b == home).cmp(&(a == home))
                                })
                                .then(a.cmp(&b))
                        });
                        idx
                    })
                    .collect()
            })
            .collect();
    }

    fn task_index(task: TaskKind) -> usize {
        TaskKind::all().iter().position(|&t| t == task).unwrap()
    }

    /// Hosted-mass score of routing `task` to `server`.
    pub fn score(&self, task: TaskKind, server: usize) -> f64 {
        self.scores[Self::task_index(task)][server]
    }

    /// Servers in descending preference order for `task`: by locality
    /// score, ties broken towards `home`, then the lower index.
    /// Precomputed — no allocation or sort on the per-arrival path.
    pub fn ranked(&self, task: TaskKind, home: usize) -> &[usize] {
        &self.pref[Self::task_index(task)][home]
    }

    /// First choice for `task` (see [`LocalityRouter::ranked`]).
    pub fn best(&self, task: TaskKind, home: usize) -> usize {
        self.ranked(task, home)[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, ModelConfig, WorkloadConfig};
    use crate::engine::warm_stats;
    use crate::placement::{uniform, PlacementAlgo};
    use crate::util::prop;

    fn world() -> (ModelConfig, ClusterConfig) {
        let m = ModelConfig::mixtral_8x7b_sim();
        let c = ClusterConfig::edge_testbed_3_for(&m);
        (m, c)
    }

    #[test]
    fn single_owner_placement_routes_to_owner() {
        // All experts on server 0 (its 70 % A100 cannot hold all of
        // Mixtral, so use the small model where one GPU fits everything).
        let m = ModelConfig::tiny();
        let c = ClusterConfig::edge_testbed_3_for(&m);
        let mut p = crate::placement::Placement::new(&m, &c);
        for l in 0..m.num_layers {
            for e in 0..m.num_experts {
                p.place(0, 0, l, e).unwrap();
            }
        }
        let r = LocalityRouter::new(&m, &p);
        for t in crate::config::TaskKind::all() {
            assert_eq!(
                r.best(t, 2),
                0,
                "the only server holding experts must win"
            );
            assert_eq!(r.score(t, 1), 0.0);
            assert_eq!(r.score(t, 2), 0.0);
        }
    }

    #[test]
    fn dancemoe_placement_routes_tasks_to_their_servers() {
        // Under the activation-aware placement, each BigBench stream's hot
        // experts sit on its home server — the router must agree.
        let (m, c) = world();
        let w = WorkloadConfig::bigbench(10.0);
        let stats = warm_stats(&m, &w);
        let p = PlacementAlgo::DanceMoE.compute(&m, &c, &stats, 1);
        let r = LocalityRouter::new(&m, &p);
        let mut matches = 0;
        for (home, stream) in w.streams.iter().enumerate() {
            if r.best(stream.task, home) == home {
                matches += 1;
            }
        }
        assert!(
            matches >= 2,
            "locality routing should mostly agree with the placement's \
             task→server mapping ({matches}/3)"
        );
    }

    #[test]
    fn rebuild_tracks_migration() {
        let (m, c) = world();
        let w = WorkloadConfig::bigbench(10.0);
        let stats = warm_stats(&m, &w);
        let uni = uniform::place(&m, &c);
        let dance = PlacementAlgo::DanceMoE.compute(&m, &c, &stats, 1);
        let mut r = LocalityRouter::new(&m, &uni);
        let before: Vec<f64> = (0..3)
            .map(|n| r.score(w.streams[0].task, n))
            .collect();
        r.rebuild(&dance);
        let after: Vec<f64> =
            (0..3).map(|n| r.score(w.streams[0].task, n)).collect();
        assert_ne!(before, after, "rebuild must pick up the new placement");
    }

    #[test]
    fn prop_ranked_is_a_permutation_maximizing_hosted_mass() {
        let (m, c) = world();
        let w = WorkloadConfig::bigbench(10.0);
        let stats = warm_stats(&m, &w);
        let placements = [
            uniform::place(&m, &c),
            PlacementAlgo::DanceMoE.compute(&m, &c, &stats, 1),
            PlacementAlgo::Eplb.compute(&m, &c, &stats, 1),
        ];
        prop::check("router targets max hosted mass", 60, |g| {
            let p = g.pick(&placements);
            let task = *g.pick(&crate::config::TaskKind::all());
            let home = g.usize_in(0, 2);
            let r = LocalityRouter::new(&m, p);
            let order = r.ranked(task, home);
            let mut sorted = order.to_vec();
            sorted.sort_unstable();
            prop::assert_prop(
                sorted == vec![0, 1, 2],
                "ranked must be a permutation of all servers",
            );
            for pair in order.windows(2) {
                prop::assert_prop(
                    r.score(task, pair[0]) >= r.score(task, pair[1]),
                    "preference order must be score-descending",
                );
            }
            // the chosen server hosts at least as much of the task's
            // activation mass as every alternative
            let profile =
                crate::trace::TaskProfile::build(task, &m);
            let best_mass = hosted_mass(&profile, p, order[0]);
            for n in 0..3 {
                prop::assert_prop(
                    best_mass >= hosted_mass(&profile, p, n),
                    "router picked a server with less hosted mass",
                );
            }
        });
    }
}
