//! Locality-aware, **replica-aware** request routing — the paper's
//! input-locality insight applied *online*.
//!
//! DanceMoE's placement concentrates each task's hot experts near the
//! server whose stream activates them (§III-B); the router closes the loop
//! from the other side: score every server by the activation mass of the
//! request's task profile it hosts under the *current* placement, and send
//! the request to the best-scoring server. Under backpressure the router
//! spills down its preference list instead of shedding outright. Scores
//! are precomputed per (task, server) and rebuilt after migrations.
//!
//! With the replica autoscaler in play a task's hot experts are often
//! hosted by *several* servers at once. Always preferring the single
//! best-scoring server would turn every replica set into one hot queue —
//! so the capacity-aware order ([`LocalityRouter::ranked_capacity`])
//! treats servers whose score is within the replica band of the best as
//! equivalent replicas and splits traffic across them by **residual
//! capacity** instead. Draining replicas never appear in any order: the
//! scores are computed from `Placement::server_has`, which a drain clears
//! immediately.

use crate::config::{ModelConfig, TaskKind};
use crate::placement::Placement;
use crate::trace::TaskProfile;

/// Weight a host-staged (not HBM-resident) expert contributes to the
/// hosted-mass score, relative to an HBM replica's 1.0. A staged expert
/// is *not* free — a hit pays the PCIe promotion load
/// (`load_s × (1 − offload_prefetch_overlap)`, ~11 ms for a Mixtral
/// expert over 16 GB/s under the default cost model) — but it is far
/// cheaper than re-fetching the weights remotely or round-tripping every
/// activation batch, so the router must not score it as absent either.
/// The default halves the credit: the modeled promotion costs roughly
/// half of what the residual remote traffic it avoids would.
pub const STAGED_DISCOUNT: f64 = 0.5;

/// Activation mass of `profile` hosted locally by `server` under `p`:
/// `Σ_l Σ_e profile[l][e] · 1[server holds (l, e)]`, plus
/// [`STAGED_DISCOUNT`]` · f` for experts the server only holds in its
/// host-DRAM cache tier. Ranges over `[0, num_layers]` (each layer's
/// distribution sums to 1). Without a host tier the staged term is
/// identically zero, so two-state scores are unchanged.
pub fn hosted_mass(
    profile: &TaskProfile,
    p: &Placement,
    server: usize,
) -> f64 {
    let tiered = p.has_host_tier();
    let mut acc = 0.0;
    for (l, dist) in profile.dist.iter().enumerate() {
        for (e, &f) in dist.iter().enumerate() {
            if f > 0.0 {
                if p.server_has(server, l, e) {
                    acc += f;
                } else if tiered && p.server_staged(server, l, e) {
                    acc += f * STAGED_DISCOUNT;
                }
            }
        }
    }
    acc
}

/// Precomputed per-(task, server) locality scores and preference orders.
#[derive(Debug, Clone)]
pub struct LocalityRouter {
    profiles: Vec<TaskProfile>,
    /// `scores[task][server]` — hosted activation mass.
    scores: Vec<Vec<f64>>,
    /// `pref[task][home]` — servers in descending preference order,
    /// precomputed so the per-arrival hot path is allocation-free.
    pref: Vec<Vec<Vec<usize>>>,
    num_servers: usize,
    /// Replica-band width for capacity-aware routing: servers scoring
    /// within this relative margin of the best are treated as equivalent
    /// replicas and ordered by residual capacity instead of score.
    pub capacity_band: f64,
}

impl LocalityRouter {
    /// Build the router against an initial placement. Profiles are the
    /// deterministic task profiles of the model (the same tables the
    /// engine's gate samples from).
    pub fn new(model: &ModelConfig, p: &Placement) -> LocalityRouter {
        let mut r = LocalityRouter {
            profiles: TaskProfile::build_all(model),
            scores: Vec::new(),
            pref: Vec::new(),
            num_servers: p.num_servers,
            capacity_band: 0.25,
        };
        r.rebuild(p);
        r
    }

    /// Recompute the score table and preference permutations against a
    /// (possibly migrated) placement.
    pub fn rebuild(&mut self, p: &Placement) {
        self.scores = self
            .profiles
            .iter()
            .map(|prof| {
                (0..self.num_servers)
                    .map(|n| hosted_mass(prof, p, n))
                    .collect()
            })
            .collect();
        self.pref = self
            .scores
            .iter()
            .map(|row| {
                (0..self.num_servers)
                    .map(|home| {
                        let mut idx: Vec<usize> =
                            (0..self.num_servers).collect();
                        idx.sort_by(|&a, &b| {
                            row[b]
                                .partial_cmp(&row[a])
                                .unwrap()
                                .then_with(|| {
                                    (b == home).cmp(&(a == home))
                                })
                                .then(a.cmp(&b))
                        });
                        idx
                    })
                    .collect()
            })
            .collect();
    }

    fn task_index(task: TaskKind) -> usize {
        TaskKind::all().iter().position(|&t| t == task).unwrap()
    }

    /// Hosted-mass score of routing `task` to `server`.
    pub fn score(&self, task: TaskKind, server: usize) -> f64 {
        self.scores[Self::task_index(task)][server]
    }

    /// Servers in descending preference order for `task`: by locality
    /// score, ties broken towards `home`, then the lower index.
    /// Precomputed — no allocation or sort on the per-arrival path.
    pub fn ranked(&self, task: TaskKind, home: usize) -> &[usize] {
        &self.pref[Self::task_index(task)][home]
    }

    /// First choice for `task` (see [`LocalityRouter::ranked`]).
    pub fn best(&self, task: TaskKind, home: usize) -> usize {
        self.ranked(task, home)[0]
    }

    /// Replica-aware preference order: servers whose locality score is
    /// within the replica band of the best (`score ≥ best × (1 − band)`)
    /// are equivalent replica holders and are ordered by **residual
    /// capacity** (descending) — so traffic splits across a hot task's
    /// replicas by available headroom instead of piling onto one queue.
    /// Out-of-band servers follow in score order. Ties break toward
    /// `home`, then the lower index. Always a permutation of all servers.
    ///
    /// `residual` is whatever queue headroom the caller routes against:
    /// the whole server queue in single-tenant gateways, or the *routed
    /// request's tenant queue* under multi-tenant admission
    /// ([`crate::serve::admission::AdmissionController::tenant_residual`])
    /// — so each tenant spills across the replica band by its own
    /// remaining room, never by headroom another tenant owns.
    pub fn ranked_capacity(
        &self,
        task: TaskKind,
        home: usize,
        residual: &[usize],
    ) -> Vec<usize> {
        let mut idx = Vec::new();
        self.ranked_capacity_into(task, home, residual, &mut idx);
        idx
    }

    /// Allocation-free form of [`LocalityRouter::ranked_capacity`]: fills
    /// `out` with the same permutation. The gateway calls this once per
    /// arrival with a reused buffer, so the per-arrival routing path
    /// allocates nothing beyond the admitted request itself.
    pub fn ranked_capacity_into(
        &self,
        task: TaskKind,
        home: usize,
        residual: &[usize],
        out: &mut Vec<usize>,
    ) {
        let row = &self.scores[Self::task_index(task)];
        let best = row.iter().cloned().fold(0.0f64, f64::max);
        let band = best * (1.0 - self.capacity_band);
        let res = |s: usize| residual.get(s).copied().unwrap_or(0);
        out.clear();
        out.extend(0..self.num_servers);
        out.sort_by(|&a, &b| {
            let ia = row[a] >= band;
            let ib = row[b] >= band;
            // in-band servers first
            ib.cmp(&ia)
                .then_with(|| {
                    if ia && ib {
                        // within the band: most residual capacity first
                        res(b).cmp(&res(a))
                    } else {
                        // outside: fall back to score order
                        row[b].partial_cmp(&row[a]).unwrap()
                    }
                })
                .then_with(|| (b == home).cmp(&(a == home)))
                .then(a.cmp(&b))
        });
    }

    /// Split `total` requests across the replica band proportionally to
    /// residual capacity (largest-remainder rounding, so the counts always
    /// conserve `total` exactly). Out-of-band servers get 0; if no server
    /// has residual capacity the whole count falls to `home` (which will
    /// shed — conservation still holds, nothing vanishes silently).
    pub fn split_counts(
        &self,
        task: TaskKind,
        home: usize,
        total: u64,
        residual: &[usize],
    ) -> Vec<u64> {
        let row = &self.scores[Self::task_index(task)];
        let best = row.iter().cloned().fold(0.0f64, f64::max);
        let band = best * (1.0 - self.capacity_band);
        let weights: Vec<f64> = (0..self.num_servers)
            .map(|s| {
                if row[s] >= band {
                    residual.get(s).copied().unwrap_or(0) as f64
                } else {
                    0.0
                }
            })
            .collect();
        largest_remainder_split(total, &weights, home)
    }
}

/// Apportion `total` by `weights` with largest-remainder rounding: the
/// result sums to exactly `total`. All-zero weights send everything to
/// `fallback`.
fn largest_remainder_split(
    total: u64,
    weights: &[f64],
    fallback: usize,
) -> Vec<u64> {
    let n = weights.len();
    let mut out = vec![0u64; n];
    if n == 0 {
        return out;
    }
    let sum: f64 = weights.iter().sum();
    if sum <= 0.0 {
        out[fallback.min(n - 1)] = total;
        return out;
    }
    let mut frac: Vec<(f64, usize)> = Vec::with_capacity(n);
    let mut assigned = 0u64;
    for (i, &wt) in weights.iter().enumerate() {
        let exact = total as f64 * wt / sum;
        let fl = exact.floor();
        out[i] = fl as u64;
        assigned += out[i];
        frac.push((exact - fl, i));
    }
    frac.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    let mut left = total.saturating_sub(assigned);
    let mut j = 0;
    while left > 0 {
        let (_, i) = frac[j % n];
        out[i] += 1;
        left -= 1;
        j += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, ModelConfig, WorkloadConfig};
    use crate::engine::warm_stats;
    use crate::placement::{uniform, PlacementAlgo};
    use crate::util::prop;

    fn world() -> (ModelConfig, ClusterConfig) {
        let m = ModelConfig::mixtral_8x7b_sim();
        let c = ClusterConfig::edge_testbed_3_for(&m);
        (m, c)
    }

    #[test]
    fn single_owner_placement_routes_to_owner() {
        // All experts on server 0 (its 70 % A100 cannot hold all of
        // Mixtral, so use the small model where one GPU fits everything).
        let m = ModelConfig::tiny();
        let c = ClusterConfig::edge_testbed_3_for(&m);
        let mut p = crate::placement::Placement::new(&m, &c);
        for l in 0..m.num_layers {
            for e in 0..m.num_experts {
                p.place(0, 0, l, e).unwrap();
            }
        }
        let r = LocalityRouter::new(&m, &p);
        for t in crate::config::TaskKind::all() {
            assert_eq!(
                r.best(t, 2),
                0,
                "the only server holding experts must win"
            );
            assert_eq!(r.score(t, 1), 0.0);
            assert_eq!(r.score(t, 2), 0.0);
        }
    }

    #[test]
    fn staged_experts_score_discounted_not_absent() {
        // Cache-aware routing: a server holding a task's experts only in
        // its host-DRAM tier earns exactly the discounted mass — more
        // than absent, strictly less than HBM residency.
        let m = ModelConfig::tiny();
        let mut c = ClusterConfig::edge_testbed_3_for(&m);
        c.servers[1].host_mem_bytes =
            m.expert_bytes * m.total_experts() as u64;
        let mut p = crate::placement::Placement::new(&m, &c);
        for l in 0..m.num_layers {
            for e in 0..m.num_experts {
                p.place(0, 0, l, e).unwrap();
            }
        }
        let bare = LocalityRouter::new(&m, &p);
        for l in 0..m.num_layers {
            for e in 0..m.num_experts {
                p.stage_host(1, l, e).unwrap();
            }
        }
        let staged = LocalityRouter::new(&m, &p);
        for t in crate::config::TaskKind::all() {
            assert_eq!(bare.score(t, 1), 0.0, "nothing staged yet");
            assert!(staged.score(t, 1) > 0.0, "staged mass must count");
            assert!(
                staged.score(t, 1) < staged.score(t, 0),
                "HBM residency must still outrank the host tier"
            );
            assert!(
                (staged.score(t, 1) - STAGED_DISCOUNT * staged.score(t, 0))
                    .abs()
                    < 1e-12,
                "staged credit is exactly the discounted full mass"
            );
            assert_eq!(staged.best(t, 1), 0, "full residency wins routing");
        }
    }

    #[test]
    fn dancemoe_placement_routes_tasks_to_their_servers() {
        // Under the activation-aware placement, each BigBench stream's hot
        // experts sit on its home server — the router must agree.
        let (m, c) = world();
        let w = WorkloadConfig::bigbench(10.0);
        let stats = warm_stats(&m, &w);
        let p = PlacementAlgo::DanceMoE.compute(&m, &c, &stats, 1);
        let r = LocalityRouter::new(&m, &p);
        let mut matches = 0;
        for (home, stream) in w.streams.iter().enumerate() {
            if r.best(stream.task, home) == home {
                matches += 1;
            }
        }
        assert!(
            matches >= 2,
            "locality routing should mostly agree with the placement's \
             task→server mapping ({matches}/3)"
        );
    }

    #[test]
    fn rebuild_tracks_migration() {
        let (m, c) = world();
        let w = WorkloadConfig::bigbench(10.0);
        let stats = warm_stats(&m, &w);
        let uni = uniform::place(&m, &c);
        let dance = PlacementAlgo::DanceMoE.compute(&m, &c, &stats, 1);
        let mut r = LocalityRouter::new(&m, &uni);
        let before: Vec<f64> = (0..3)
            .map(|n| r.score(w.streams[0].task, n))
            .collect();
        r.rebuild(&dance);
        let after: Vec<f64> =
            (0..3).map(|n| r.score(w.streams[0].task, n)).collect();
        assert_ne!(before, after, "rebuild must pick up the new placement");
    }

    #[test]
    fn draining_replica_invisible_to_scores() {
        // Scale-in safety at the gateway layer: the router's scores come
        // from `server_has`, which a drain clears immediately — a draining
        // replica can never attract new traffic.
        let m = ModelConfig::tiny();
        let c = ClusterConfig::edge_testbed_3_for(&m);
        let mut p = crate::placement::Placement::new(&m, &c);
        for l in 0..m.num_layers {
            for e in 0..m.num_experts {
                p.place(0, 0, l, e).unwrap();
                p.place(1, 0, l, e).unwrap();
            }
        }
        let before = LocalityRouter::new(&m, &p);
        for l in 0..m.num_layers {
            for e in 0..m.num_experts {
                p.begin_drain(1, 0, l, e).unwrap();
            }
        }
        let after = LocalityRouter::new(&m, &p);
        for t in crate::config::TaskKind::all() {
            assert!(before.score(t, 1) > 0.0);
            assert_eq!(after.score(t, 1), 0.0, "draining server must score 0");
            assert_eq!(after.best(t, 1), 0, "all traffic shifts to server 0");
        }
    }

    #[test]
    fn prop_ranked_capacity_is_permutation_splitting_by_residual() {
        let (m, c) = world();
        let w = WorkloadConfig::bigbench(10.0);
        let stats = warm_stats(&m, &w);
        let placements = [
            uniform::place(&m, &c),
            PlacementAlgo::DanceMoE.compute(&m, &c, &stats, 1),
        ];
        prop::check("capacity order splits the replica band", 80, |g| {
            let p = g.pick(&placements);
            let task = *g.pick(&crate::config::TaskKind::all());
            let home = g.usize_in(0, 2);
            let residual =
                [g.usize_in(0, 64), g.usize_in(0, 64), g.usize_in(0, 64)];
            let r = LocalityRouter::new(&m, p);
            let order = r.ranked_capacity(task, home, &residual);
            let mut sorted = order.clone();
            sorted.sort_unstable();
            prop::assert_prop(
                sorted == vec![0, 1, 2],
                "ranked_capacity must be a permutation of all servers",
            );
            // within the replica band, residual capacity must not increase
            // down the order; and no out-of-band server may precede an
            // in-band one
            let best =
                (0..3).map(|s| r.score(task, s)).fold(0.0f64, f64::max);
            let band = best * (1.0 - r.capacity_band);
            let in_band: Vec<bool> =
                order.iter().map(|&s| r.score(task, s) >= band).collect();
            for i in 1..order.len() {
                prop::assert_prop(
                    in_band[i - 1] || !in_band[i],
                    "in-band server ranked below an out-of-band one",
                );
                if in_band[i - 1] && in_band[i] {
                    prop::assert_prop(
                        residual[order[i - 1]] >= residual[order[i]],
                        "replica band not ordered by residual capacity",
                    );
                }
            }
        });
    }

    #[test]
    fn prop_split_counts_conserves_requests() {
        let (m, c) = world();
        let w = WorkloadConfig::bigbench(10.0);
        let stats = warm_stats(&m, &w);
        let placements = [
            uniform::place(&m, &c),
            PlacementAlgo::DanceMoE.compute(&m, &c, &stats, 1),
            PlacementAlgo::Eplb.compute(&m, &c, &stats, 1),
        ];
        prop::check("traffic split conserves request count", 100, |g| {
            let p = g.pick(&placements);
            let task = *g.pick(&crate::config::TaskKind::all());
            let home = g.usize_in(0, 2);
            let total = g.usize_in(0, 500) as u64;
            let residual =
                [g.usize_in(0, 32), g.usize_in(0, 32), g.usize_in(0, 32)];
            let r = LocalityRouter::new(&m, p);
            let counts = r.split_counts(task, home, total, &residual);
            prop::assert_prop(
                counts.iter().sum::<u64>() == total,
                "split must conserve the request count exactly",
            );
            // when the replica band has any capacity, a zero-capacity
            // server gets nothing (otherwise everything falls to home)
            let best =
                (0..3).map(|s| r.score(task, s)).fold(0.0f64, f64::max);
            let band = best * (1.0 - r.capacity_band);
            let band_capacity: usize = (0..3)
                .filter(|&s| r.score(task, s) >= band)
                .map(|s| residual[s])
                .sum();
            if band_capacity > 0 {
                for (s, &n) in counts.iter().enumerate() {
                    if residual[s] == 0 {
                        prop::assert_prop(
                            n == 0,
                            "zero-capacity server must receive nothing",
                        );
                    }
                }
            } else {
                prop::assert_prop(
                    counts[home] == total,
                    "no band capacity: everything falls to home",
                );
            }
        });
    }

    #[test]
    fn prop_ranked_is_a_permutation_maximizing_hosted_mass() {
        let (m, c) = world();
        let w = WorkloadConfig::bigbench(10.0);
        let stats = warm_stats(&m, &w);
        let placements = [
            uniform::place(&m, &c),
            PlacementAlgo::DanceMoE.compute(&m, &c, &stats, 1),
            PlacementAlgo::Eplb.compute(&m, &c, &stats, 1),
        ];
        prop::check("router targets max hosted mass", 60, |g| {
            let p = g.pick(&placements);
            let task = *g.pick(&crate::config::TaskKind::all());
            let home = g.usize_in(0, 2);
            let r = LocalityRouter::new(&m, p);
            let order = r.ranked(task, home);
            let mut sorted = order.to_vec();
            sorted.sort_unstable();
            prop::assert_prop(
                sorted == vec![0, 1, 2],
                "ranked must be a permutation of all servers",
            );
            for pair in order.windows(2) {
                prop::assert_prop(
                    r.score(task, pair[0]) >= r.score(task, pair[1]),
                    "preference order must be score-descending",
                );
            }
            // the chosen server hosts at least as much of the task's
            // activation mass as every alternative
            let profile =
                crate::trace::TaskProfile::build(task, &m);
            let best_mass = hosted_mass(&profile, p, order[0]);
            for n in 0..3 {
                prop::assert_prop(
                    best_mass >= hosted_mass(&profile, p, n),
                    "router picked a server with less hosted mass",
                );
            }
        });
    }
}
