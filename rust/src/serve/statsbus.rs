//! The live stats bus: turns the engine's *cumulative* activation
//! statistics into per-interval deltas for online consumers.
//!
//! The paper's Global Scheduler runs from "activation statistics reported"
//! by the serving layer (§III-A). Offline replays pre-seed that history;
//! the gateway instead publishes a [`StatsDelta`] every interval — the
//! token-weighted expert activations observed *in that window alone* —
//! which the coordinator ingests into its decayed history. Placement
//! refresh and migration then run entirely from online measurements.
//!
//! With multi-tenant serving the bus carries a second stream: the
//! [`TenantBus`] snapshot-differences the gateway's cumulative completion
//! records and per-tenant shed counters into per-interval
//! [`TenantWindow`]s, from which the coordinator derives each tenant's
//! SLO pressure (see [`crate::serve::tenant`]).

use crate::config::ModelConfig;
use crate::engine::ServeReport;
use crate::moe::ActivationStats;

/// One interval's activation observations.
#[derive(Debug, Clone)]
pub struct StatsDelta {
    /// Interval end (virtual seconds).
    pub t_s: f64,
    /// Window length the delta covers.
    pub window_s: f64,
    /// Token-activations observed in the window (Σ over the table).
    pub tokens: f64,
    /// Per-(server, layer, expert) activation counts for the window.
    pub stats: ActivationStats,
}

/// Converts a cumulative statistics table into per-interval deltas by
/// snapshot differencing.
#[derive(Debug, Clone)]
pub struct StatsBus {
    snapshot: ActivationStats,
    last_t: f64,
    /// intervals published so far
    pub published: u64,
}

impl StatsBus {
    pub fn new(model: &ModelConfig, num_servers: usize) -> StatsBus {
        StatsBus {
            snapshot: ActivationStats::new(model, num_servers),
            last_t: 0.0,
            published: 0,
        }
    }

    /// Publish the delta of `cumulative` since the previous `collect`.
    pub fn collect(
        &mut self,
        cumulative: &ActivationStats,
        t: f64,
    ) -> StatsDelta {
        let mut delta = self.snapshot.clone();
        delta.reset();
        let mut tokens = 0.0;
        for n in 0..delta.num_servers() {
            for l in 0..delta.num_layers {
                for e in 0..delta.num_experts {
                    let inc = (cumulative.raw(n, l, e)
                        - self.snapshot.raw(n, l, e))
                    .max(0.0);
                    if inc > 0.0 {
                        delta.record(n, l, e, inc);
                        tokens += inc;
                    }
                }
            }
        }
        self.snapshot = cumulative.clone();
        let window_s = (t - self.last_t).max(1e-9);
        self.last_t = t;
        self.published += 1;
        StatsDelta {
            t_s: t,
            window_s,
            tokens,
            stats: delta,
        }
    }
}

/// One tenant's serving observations over a stats-bus window.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TenantWindow {
    /// Requests of this tenant completed in the window.
    pub completed: u64,
    /// Of those, how many exceeded the tenant's SLO. Observability only:
    /// the pressure signal ([`crate::serve::tenant::window_pressure`])
    /// reads `p95_s` and `shed`, not this count.
    pub violations: u64,
    /// Requests of this tenant shed at admission in the window.
    pub shed: u64,
    /// p95 latency over the window's completions (0 when idle).
    pub p95_s: f64,
}

/// Per-interval tenant accounting: snapshot-differences the cumulative
/// completion records and per-tenant shed counters into windows, the same
/// way [`StatsBus`] differences the activation table.
#[derive(Debug, Clone)]
pub struct TenantBus {
    /// Per-tenant SLO targets (window violation threshold).
    slos: Vec<f64>,
    records_seen: usize,
    shed_seen: Vec<u64>,
}

impl TenantBus {
    pub fn new(slos: &[f64]) -> TenantBus {
        TenantBus {
            slos: slos.to_vec(),
            records_seen: 0,
            shed_seen: vec![0; slos.len()],
        }
    }

    pub fn num_tenants(&self) -> usize {
        self.slos.len()
    }

    /// The per-tenant SLO targets the windows are scored against — the
    /// single source the gateway also derives its pressures from.
    pub fn slos(&self) -> &[f64] {
        &self.slos
    }

    /// Publish the per-tenant windows covering everything since the last
    /// `collect`: new completion records in `report` plus the growth of
    /// the cumulative `shed_by_tenant` counters. Grouping and violation
    /// counting go through the canonical rule
    /// ([`crate::engine::metrics::tenant_slices`]), applied to the
    /// window's record slice.
    pub fn collect(
        &mut self,
        report: &ServeReport,
        shed_by_tenant: &[u64],
    ) -> Vec<TenantWindow> {
        let n = self.slos.len();
        let mut wins = vec![TenantWindow::default(); n];
        let (lat, violations) = crate::engine::metrics::tenant_slices(
            &report.records[self.records_seen..],
            &self.slos,
        );
        self.records_seen = report.records.len();
        for t in 0..n {
            wins[t].completed = lat[t].len() as u64;
            wins[t].violations = violations[t];
            wins[t].p95_s = crate::util::stats::percentile(&lat[t], 0.95);
            let cum = shed_by_tenant.get(t).copied().unwrap_or(0);
            wins[t].shed = cum.saturating_sub(self.shed_seen[t]);
            self.shed_seen[t] = cum;
        }
        wins
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::engine::RequestRecord;

    #[test]
    fn deltas_partition_the_cumulative_stream() {
        let m = ModelConfig::tiny();
        let mut bus = StatsBus::new(&m, 2);
        let mut cum = ActivationStats::new(&m, 2);

        cum.record(0, 0, 1, 10.0);
        cum.record(1, 2, 3, 5.0);
        let d1 = bus.collect(&cum, 60.0);
        assert_eq!(d1.tokens, 15.0);
        assert_eq!(d1.stats.raw(0, 0, 1), 10.0);
        assert_eq!(d1.window_s, 60.0);

        cum.record(0, 0, 1, 4.0);
        let d2 = bus.collect(&cum, 120.0);
        assert_eq!(d2.tokens, 4.0, "second delta sees only the increment");
        assert_eq!(d2.stats.raw(0, 0, 1), 4.0);
        assert_eq!(d2.stats.raw(1, 2, 3), 0.0);
        assert_eq!(d2.window_s, 60.0);
        assert_eq!(bus.published, 2);

        // no new activity → empty delta
        let d3 = bus.collect(&cum, 180.0);
        assert_eq!(d3.tokens, 0.0);
    }

    fn push_rec(report: &mut ServeReport, id: usize, tenant: usize, lat: f64) {
        report.push(RequestRecord {
            id,
            server: 0,
            tenant,
            arrival_s: 0.0,
            done_s: lat,
            latency_s: lat,
            local_token_invocations: 0.0,
            remote_token_invocations: 0.0,
        });
    }

    #[test]
    fn tenant_windows_partition_records_and_sheds() {
        let mut report = ServeReport::new(1, 60.0);
        let mut bus = TenantBus::new(&[2.0, 10.0]);
        assert_eq!(bus.num_tenants(), 2);
        push_rec(&mut report, 0, 0, 1.0);
        push_rec(&mut report, 1, 0, 3.0);
        push_rec(&mut report, 2, 1, 5.0);
        let w = bus.collect(&report, &[1, 0]);
        assert_eq!(w[0].completed, 2);
        assert_eq!(w[0].violations, 1, "3.0s > 2.0s SLO");
        assert_eq!(w[0].shed, 1);
        assert_eq!(w[1].completed, 1);
        assert_eq!(w[1].violations, 0);
        assert_eq!(w[1].p95_s, 5.0);

        // the second window sees only the increments
        push_rec(&mut report, 3, 1, 20.0);
        let w = bus.collect(&report, &[1, 4]);
        assert_eq!(w[0], TenantWindow::default());
        assert_eq!(w[1].completed, 1);
        assert_eq!(w[1].violations, 1);
        assert_eq!(w[1].shed, 4);

        // an idle interval publishes empty windows
        let w = bus.collect(&report, &[1, 4]);
        assert!(w.iter().all(|x| *x == TenantWindow::default()));
    }

    #[test]
    fn delta_sum_reconstructs_cumulative() {
        let m = ModelConfig::tiny();
        let mut bus = StatsBus::new(&m, 1);
        let mut cum = ActivationStats::new(&m, 1);
        let mut rebuilt = ActivationStats::new(&m, 1);
        for step in 1..=5 {
            cum.record(0, step % 4, step % 8, step as f64);
            let d = bus.collect(&cum, step as f64 * 30.0);
            rebuilt.merge(&d.stats);
        }
        assert_eq!(rebuilt, cum);
    }
}
