//! The live stats bus: turns the engine's *cumulative* activation
//! statistics into per-interval deltas for online consumers.
//!
//! The paper's Global Scheduler runs from "activation statistics reported"
//! by the serving layer (§III-A). Offline replays pre-seed that history;
//! the gateway instead publishes a [`StatsDelta`] every interval — the
//! token-weighted expert activations observed *in that window alone* —
//! which the coordinator ingests into its decayed history. Placement
//! refresh and migration then run entirely from online measurements.
//!
//! With multi-tenant serving the bus carries a second stream: the
//! [`TenantBus`] snapshot-differences the gateway's cumulative completion
//! records and per-tenant shed counters into per-interval
//! [`TenantWindow`]s, from which the coordinator derives each tenant's
//! SLO pressure (see [`crate::serve::tenant`]).

use crate::config::ModelConfig;
use crate::engine::ServeReport;
use crate::moe::ActivationStats;

/// One interval's activation observations.
#[derive(Debug, Clone)]
pub struct StatsDelta {
    /// Interval end (virtual seconds).
    pub t_s: f64,
    /// Window length the delta covers.
    pub window_s: f64,
    /// Token-activations observed in the window (Σ over the table).
    pub tokens: f64,
    /// Per-(server, layer, expert) activation counts for the window.
    pub stats: ActivationStats,
}

/// Converts a cumulative statistics table into per-interval deltas by
/// snapshot differencing.
#[derive(Debug, Clone)]
pub struct StatsBus {
    snapshot: ActivationStats,
    last_t: f64,
    /// intervals published so far
    pub published: u64,
}

impl StatsBus {
    pub fn new(model: &ModelConfig, num_servers: usize) -> StatsBus {
        StatsBus {
            snapshot: ActivationStats::new(model, num_servers),
            last_t: 0.0,
            published: 0,
        }
    }

    /// Publish the delta of `cumulative` since the previous `collect`.
    pub fn collect(
        &mut self,
        cumulative: &ActivationStats,
        t: f64,
    ) -> StatsDelta {
        let mut delta = self.snapshot.clone();
        delta.reset();
        let mut tokens = 0.0;
        for n in 0..delta.num_servers() {
            for l in 0..delta.num_layers {
                for e in 0..delta.num_experts {
                    let inc = (cumulative.raw(n, l, e)
                        - self.snapshot.raw(n, l, e))
                    .max(0.0);
                    if inc > 0.0 {
                        delta.record(n, l, e, inc);
                        tokens += inc;
                    }
                }
            }
        }
        self.snapshot = cumulative.clone();
        let window_s = (t - self.last_t).max(1e-9);
        self.last_t = t;
        self.published += 1;
        StatsDelta {
            t_s: t,
            window_s,
            tokens,
            stats: delta,
        }
    }
}

/// One tenant's serving observations over a stats-bus window.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TenantWindow {
    /// Requests of this tenant completed in the window.
    pub completed: u64,
    /// Of those, how many exceeded the tenant's SLO. Observability only:
    /// the pressure signal ([`crate::serve::tenant::window_pressure`])
    /// reads `p95_s` and `shed`, not this count.
    pub violations: u64,
    /// Requests of this tenant shed at admission in the window.
    pub shed: u64,
    /// p95 latency over the window's completions (0 when idle).
    pub p95_s: f64,
}

/// Per-interval tenant accounting: snapshot-differences the cumulative
/// completion records and per-tenant shed counters into windows, the same
/// way [`StatsBus`] differences the activation table.
#[derive(Debug, Clone)]
pub struct TenantBus {
    /// Per-tenant SLO targets (window violation threshold).
    slos: Vec<f64>,
    records_seen: usize,
    shed_seen: Vec<u64>,
}

impl TenantBus {
    pub fn new(slos: &[f64]) -> TenantBus {
        TenantBus {
            slos: slos.to_vec(),
            records_seen: 0,
            shed_seen: vec![0; slos.len()],
        }
    }

    pub fn num_tenants(&self) -> usize {
        self.slos.len()
    }

    /// The per-tenant SLO targets the windows are scored against — the
    /// single source the gateway also derives its pressures from.
    pub fn slos(&self) -> &[f64] {
        &self.slos
    }

    /// Publish the per-tenant windows covering everything since the last
    /// `collect`: new completion records in `report` plus the growth of
    /// the cumulative `shed_by_tenant` counters. Grouping and violation
    /// counting go through the canonical rule
    /// ([`crate::engine::metrics::tenant_slices`]), applied to the
    /// window's record slice.
    pub fn collect(
        &mut self,
        report: &ServeReport,
        shed_by_tenant: &[u64],
    ) -> Vec<TenantWindow> {
        let n = self.slos.len();
        let mut wins = vec![TenantWindow::default(); n];
        let (lat, violations) = crate::engine::metrics::tenant_slices(
            &report.records[self.records_seen..],
            &self.slos,
        );
        self.records_seen = report.records.len();
        for t in 0..n {
            wins[t].completed = lat[t].len() as u64;
            wins[t].violations = violations[t];
            wins[t].p95_s = crate::util::stats::percentile(&lat[t], 0.95);
            let cum = shed_by_tenant.get(t).copied().unwrap_or(0);
            wins[t].shed = cum.saturating_sub(self.shed_seen[t]);
            self.shed_seen[t] = cum;
        }
        wins
    }
}

/// One region's serving observations over a federation-exchange window —
/// the cross-gateway pressure signal regional gateways trade (the
/// region-level analogue of [`TenantWindow`], plus live capacity).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RegionWindow {
    /// Requests this region's engine completed in the window.
    pub completed: u64,
    /// Requests shed at this region's admission in the window.
    pub shed: u64,
    /// p95 latency over the window's completions (0 when idle).
    pub p95_s: f64,
    /// Live admission queue depth at publish time (not a delta).
    pub queued: usize,
    /// Live admission headroom at publish time (Σ queue bounds − depths):
    /// the spill room this region advertises to its peers.
    pub residual: usize,
    /// Per-tenant slices of `residual` (`[tenant]`, hard bounds only):
    /// spill targeting requires headroom in the *forwarded tenant's* own
    /// queues, not just somewhere in the region.
    pub residual_by_tenant: Vec<usize>,
    /// Derived scalar pressure — relative p95 overshoot + window shed
    /// fraction, capped like tenant pressure. Peers avoid spilling into a
    /// pressured region; the region's own coordinator relaxes its
    /// migration threshold under it. Forwarded-in completions count here
    /// under their *origin* arrival clock, but they leave the origin at
    /// arrival time (spill happens before any queueing there), so the
    /// only latency a receiver inherits is the inter-region transfer —
    /// it cannot be pushed over the spill threshold by congestion it
    /// did not cause.
    pub pressure: f64,
}

/// Snapshot-differencing bus for one region's gateway: completions and
/// sheds since the previous exchange (the same differencing pattern as
/// [`TenantBus`], aggregated across tenants), annotated with the live
/// queue state the spill policy routes on.
#[derive(Debug, Clone)]
pub struct RegionBus {
    /// Region-level latency SLO the windows are scored against.
    slo_s: f64,
    records_seen: usize,
    shed_seen: u64,
}

impl RegionBus {
    pub fn new(slo_s: f64) -> RegionBus {
        RegionBus {
            slo_s,
            records_seen: 0,
            shed_seen: 0,
        }
    }

    /// Publish the window covering everything since the last `collect`:
    /// new completion records in `report` plus the growth of the
    /// cumulative shed counter, stamped with the live `queued`/`residual`
    /// admission state (`residual_by_tenant` = the per-tenant slices).
    pub fn collect(
        &mut self,
        report: &ServeReport,
        shed_cum: u64,
        queued: usize,
        residual: usize,
        residual_by_tenant: Vec<usize>,
    ) -> RegionWindow {
        let recs = &report.records[self.records_seen..];
        self.records_seen = report.records.len();
        let lat: Vec<f64> =
            recs.iter().map(|r| r.latency_s).collect();
        let completed = lat.len() as u64;
        let p95_s = crate::util::stats::percentile(&lat, 0.95);
        let shed = shed_cum.saturating_sub(self.shed_seen);
        self.shed_seen = shed_cum;
        let mut pressure = 0.0;
        if completed > 0 && self.slo_s > 0.0 {
            pressure += (p95_s / self.slo_s - 1.0).max(0.0);
        }
        let offered = completed + shed;
        if offered > 0 {
            pressure += shed as f64 / offered as f64;
        }
        pressure =
            pressure.min(crate::serve::tenant::MAX_TENANT_PRESSURE);
        RegionWindow {
            completed,
            shed,
            p95_s,
            queued,
            residual,
            residual_by_tenant,
            pressure,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::engine::RequestRecord;

    #[test]
    fn deltas_partition_the_cumulative_stream() {
        let m = ModelConfig::tiny();
        let mut bus = StatsBus::new(&m, 2);
        let mut cum = ActivationStats::new(&m, 2);

        cum.record(0, 0, 1, 10.0);
        cum.record(1, 2, 3, 5.0);
        let d1 = bus.collect(&cum, 60.0);
        assert_eq!(d1.tokens, 15.0);
        assert_eq!(d1.stats.raw(0, 0, 1), 10.0);
        assert_eq!(d1.window_s, 60.0);

        cum.record(0, 0, 1, 4.0);
        let d2 = bus.collect(&cum, 120.0);
        assert_eq!(d2.tokens, 4.0, "second delta sees only the increment");
        assert_eq!(d2.stats.raw(0, 0, 1), 4.0);
        assert_eq!(d2.stats.raw(1, 2, 3), 0.0);
        assert_eq!(d2.window_s, 60.0);
        assert_eq!(bus.published, 2);

        // no new activity → empty delta
        let d3 = bus.collect(&cum, 180.0);
        assert_eq!(d3.tokens, 0.0);
    }

    fn push_rec(report: &mut ServeReport, id: usize, tenant: usize, lat: f64) {
        report.push(RequestRecord {
            id,
            server: 0,
            tenant,
            arrival_s: 0.0,
            done_s: lat,
            latency_s: lat,
            local_token_invocations: 0.0,
            remote_token_invocations: 0.0,
        });
    }

    #[test]
    fn tenant_windows_partition_records_and_sheds() {
        let mut report = ServeReport::new(1, 60.0);
        let mut bus = TenantBus::new(&[2.0, 10.0]);
        assert_eq!(bus.num_tenants(), 2);
        push_rec(&mut report, 0, 0, 1.0);
        push_rec(&mut report, 1, 0, 3.0);
        push_rec(&mut report, 2, 1, 5.0);
        let w = bus.collect(&report, &[1, 0]);
        assert_eq!(w[0].completed, 2);
        assert_eq!(w[0].violations, 1, "3.0s > 2.0s SLO");
        assert_eq!(w[0].shed, 1);
        assert_eq!(w[1].completed, 1);
        assert_eq!(w[1].violations, 0);
        assert_eq!(w[1].p95_s, 5.0);

        // the second window sees only the increments
        push_rec(&mut report, 3, 1, 20.0);
        let w = bus.collect(&report, &[1, 4]);
        assert_eq!(w[0], TenantWindow::default());
        assert_eq!(w[1].completed, 1);
        assert_eq!(w[1].violations, 1);
        assert_eq!(w[1].shed, 4);

        // an idle interval publishes empty windows
        let w = bus.collect(&report, &[1, 4]);
        assert!(w.iter().all(|x| *x == TenantWindow::default()));
    }

    #[test]
    fn region_windows_difference_and_pressure() {
        let mut report = ServeReport::new(1, 60.0);
        let mut bus = RegionBus::new(4.0);
        // inside the SLO, nothing shed: zero pressure
        push_rec(&mut report, 0, 0, 1.0);
        push_rec(&mut report, 1, 0, 2.0);
        let w = bus.collect(&report, 0, 5, 11, vec![7, 4]);
        assert_eq!(w.completed, 2);
        assert_eq!(w.shed, 0);
        assert_eq!(w.queued, 5);
        assert_eq!(w.residual, 11);
        assert_eq!(w.residual_by_tenant, vec![7, 4]);
        assert_eq!(w.pressure, 0.0);
        // the next window sees only increments; overshoot + sheds build
        // pressure (p95 8.0 at SLO 4.0 → +1.0; 2 shed of 4 offered → +0.5)
        push_rec(&mut report, 2, 0, 8.0);
        push_rec(&mut report, 3, 0, 8.0);
        let w = bus.collect(&report, 2, 0, 0, vec![0, 0]);
        assert_eq!(w.completed, 2);
        assert_eq!(w.shed, 2);
        assert!((w.pressure - 1.5).abs() < 1e-12, "pressure {}", w.pressure);
        // idle window: no completions, no new sheds, no pressure
        let w = bus.collect(&report, 2, 0, 16, vec![8, 8]);
        assert_eq!(w.completed, 0);
        assert_eq!(w.shed, 0);
        assert_eq!(w.pressure, 0.0);
    }

    #[test]
    fn zero_width_windows_clamp_not_divide_by_zero() {
        // two collects at the same instant: the second window's length
        // clamps to the epsilon floor instead of 0, so downstream
        // rates (tokens / window_s) stay finite
        let m = ModelConfig::tiny();
        let mut bus = StatsBus::new(&m, 1);
        let mut cum = ActivationStats::new(&m, 1);
        cum.record(0, 0, 0, 3.0);
        let d1 = bus.collect(&cum, 30.0);
        assert_eq!(d1.window_s, 30.0);
        cum.record(0, 0, 0, 2.0);
        let d2 = bus.collect(&cum, 30.0);
        assert!(d2.window_s > 0.0, "zero-width window must clamp");
        assert_eq!(d2.tokens, 2.0);
        let rate = d2.tokens / d2.window_s;
        assert!(rate.is_finite());
        // time moving backwards (a mis-ordered publisher) also clamps
        let d3 = bus.collect(&cum, 20.0);
        assert!(d3.window_s > 0.0);
    }

    #[test]
    fn counter_resets_publish_empty_not_negative() {
        // A cumulative table that goes backwards (engine swap/reset
        // between collects) must difference to an empty delta, not a
        // negative one — the bus clamps per-cell increments at 0 and
        // re-snapshots, so the stream recovers on the next interval.
        let m = ModelConfig::tiny();
        let mut bus = StatsBus::new(&m, 1);
        let mut cum = ActivationStats::new(&m, 1);
        cum.record(0, 0, 0, 10.0);
        let _ = bus.collect(&cum, 30.0);
        // reset: a fresh table with *less* accumulated than the snapshot
        let mut fresh = ActivationStats::new(&m, 1);
        fresh.record(0, 0, 0, 4.0);
        let d = bus.collect(&fresh, 60.0);
        assert_eq!(d.tokens, 0.0, "backwards counters clamp to empty");
        assert_eq!(d.stats.raw(0, 0, 0), 0.0);
        // growth after the reset differences against the new snapshot
        fresh.record(0, 0, 0, 6.0);
        let d = bus.collect(&fresh, 90.0);
        assert_eq!(d.tokens, 6.0);

        // the shed counters of the tenant and region buses saturate the
        // same way instead of wrapping
        let report = ServeReport::new(1, 60.0);
        let mut tbus = TenantBus::new(&[2.0]);
        let _ = tbus.collect(&report, &[5]);
        let w = tbus.collect(&report, &[1]); // counter went backwards
        assert_eq!(w[0].shed, 0, "tenant shed saturates at 0");
        let w = tbus.collect(&report, &[3]);
        assert_eq!(w[0].shed, 2, "recovers against the new snapshot");
        let mut rbus = RegionBus::new(4.0);
        let _ = rbus.collect(&report, 5, 0, 0, vec![]);
        let w = rbus.collect(&report, 1, 0, 0, vec![]);
        assert_eq!(w.shed, 0, "region shed saturates at 0");
    }

    #[test]
    fn first_window_covers_everything_since_construction() {
        // A bus built after traffic started still publishes a correct
        // first window: everything in the report / counters to date, and
        // a StatsBus first window spans from t = 0.
        let m = ModelConfig::tiny();
        let mut bus = StatsBus::new(&m, 1);
        let cum = ActivationStats::new(&m, 1);
        let d = bus.collect(&cum, 45.0);
        assert_eq!(d.window_s, 45.0, "first window starts at t = 0");
        assert_eq!(d.tokens, 0.0);

        let mut report = ServeReport::new(1, 60.0);
        push_rec(&mut report, 0, 0, 1.0);
        push_rec(&mut report, 1, 0, 9.0);
        let mut tbus = TenantBus::new(&[2.0]);
        let w = tbus.collect(&report, &[3]);
        assert_eq!(w[0].completed, 2, "pre-construction records counted");
        assert_eq!(w[0].violations, 1);
        assert_eq!(w[0].shed, 3, "first window takes the full counter");
        let mut rbus = RegionBus::new(4.0);
        let w = rbus.collect(&report, 3, 1, 2, vec![2]);
        assert_eq!(w.completed, 2);
        assert_eq!(w.shed, 3);
    }

    #[test]
    fn delta_sum_reconstructs_cumulative() {
        let m = ModelConfig::tiny();
        let mut bus = StatsBus::new(&m, 1);
        let mut cum = ActivationStats::new(&m, 1);
        let mut rebuilt = ActivationStats::new(&m, 1);
        for step in 1..=5 {
            cum.record(0, step % 4, step % 8, step as f64);
            let d = bus.collect(&cum, step as f64 * 30.0);
            rebuilt.merge(&d.stats);
        }
        assert_eq!(rebuilt, cum);
    }
}
