//! The live stats bus: turns the engine's *cumulative* activation
//! statistics into per-interval deltas for online consumers.
//!
//! The paper's Global Scheduler runs from "activation statistics reported"
//! by the serving layer (§III-A). Offline replays pre-seed that history;
//! the gateway instead publishes a [`StatsDelta`] every interval — the
//! token-weighted expert activations observed *in that window alone* —
//! which the coordinator ingests into its decayed history. Placement
//! refresh and migration then run entirely from online measurements.

use crate::config::ModelConfig;
use crate::moe::ActivationStats;

/// One interval's activation observations.
#[derive(Debug, Clone)]
pub struct StatsDelta {
    /// Interval end (virtual seconds).
    pub t_s: f64,
    /// Window length the delta covers.
    pub window_s: f64,
    /// Token-activations observed in the window (Σ over the table).
    pub tokens: f64,
    /// Per-(server, layer, expert) activation counts for the window.
    pub stats: ActivationStats,
}

/// Converts a cumulative statistics table into per-interval deltas by
/// snapshot differencing.
#[derive(Debug, Clone)]
pub struct StatsBus {
    snapshot: ActivationStats,
    last_t: f64,
    /// intervals published so far
    pub published: u64,
}

impl StatsBus {
    pub fn new(model: &ModelConfig, num_servers: usize) -> StatsBus {
        StatsBus {
            snapshot: ActivationStats::new(model, num_servers),
            last_t: 0.0,
            published: 0,
        }
    }

    /// Publish the delta of `cumulative` since the previous `collect`.
    pub fn collect(
        &mut self,
        cumulative: &ActivationStats,
        t: f64,
    ) -> StatsDelta {
        let mut delta = self.snapshot.clone();
        delta.reset();
        let mut tokens = 0.0;
        for n in 0..delta.num_servers() {
            for l in 0..delta.num_layers {
                for e in 0..delta.num_experts {
                    let inc = (cumulative.raw(n, l, e)
                        - self.snapshot.raw(n, l, e))
                    .max(0.0);
                    if inc > 0.0 {
                        delta.record(n, l, e, inc);
                        tokens += inc;
                    }
                }
            }
        }
        self.snapshot = cumulative.clone();
        let window_s = (t - self.last_t).max(1e-9);
        self.last_t = t;
        self.published += 1;
        StatsDelta {
            t_s: t,
            window_s,
            tokens,
            stats: delta,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn deltas_partition_the_cumulative_stream() {
        let m = ModelConfig::tiny();
        let mut bus = StatsBus::new(&m, 2);
        let mut cum = ActivationStats::new(&m, 2);

        cum.record(0, 0, 1, 10.0);
        cum.record(1, 2, 3, 5.0);
        let d1 = bus.collect(&cum, 60.0);
        assert_eq!(d1.tokens, 15.0);
        assert_eq!(d1.stats.raw(0, 0, 1), 10.0);
        assert_eq!(d1.window_s, 60.0);

        cum.record(0, 0, 1, 4.0);
        let d2 = bus.collect(&cum, 120.0);
        assert_eq!(d2.tokens, 4.0, "second delta sees only the increment");
        assert_eq!(d2.stats.raw(0, 0, 1), 4.0);
        assert_eq!(d2.stats.raw(1, 2, 3), 0.0);
        assert_eq!(d2.window_s, 60.0);
        assert_eq!(bus.published, 2);

        // no new activity → empty delta
        let d3 = bus.collect(&cum, 180.0);
        assert_eq!(d3.tokens, 0.0);
    }

    #[test]
    fn delta_sum_reconstructs_cumulative() {
        let m = ModelConfig::tiny();
        let mut bus = StatsBus::new(&m, 1);
        let mut cum = ActivationStats::new(&m, 1);
        let mut rebuilt = ActivationStats::new(&m, 1);
        for step in 1..=5 {
            cum.record(0, step % 4, step % 8, step as f64);
            let d = bus.collect(&cum, step as f64 * 30.0);
            rebuilt.merge(&d.stats);
        }
        assert_eq!(rebuilt, cum);
    }
}
