//! Multi-tenant serving: tenant identities, per-tenant arrival / weight /
//! SLO configuration, and the SLO-pressure signals that let the placement
//! refresh and the replica autoscaler repair a *specific* tenant's p95.
//!
//! A tenant is a demand source sharing the cluster with others: it offers
//! its own arrival process (its own [`ArrivalProfile`] over the workload's
//! per-server streams), competes for dequeue bandwidth through the
//! weighted-deficit admission policy
//! ([`crate::serve::admission::AdmissionController`]), sheds at its own
//! queue bound, and is held to its own latency SLO. Every interval the
//! gateway turns each tenant's window of completions and sheds into a
//! scalar **pressure** ([`window_pressure`]) — how far past its SLO the
//! tenant is running — and an **expert boost** vector
//! ([`boost_from_masses`]) that concentrates that pressure on the experts
//! the violating tenant's tasks actually activate. The coordinator lowers
//! its migration-adoption threshold under pressure and the autoscaler
//! prefers boosted experts, so control actions are scored by which
//! tenant's p95 target they repair (MoE²'s / CoMoE's multi-objective
//! framing, made operational).

use crate::config::{ClusterConfig, ModelConfig, TaskKind, WorkloadConfig};
use crate::coordinator::CoordinatorConfig;
use crate::placement::uniform;
use crate::serve::arrival::ArrivalProfile;
use crate::serve::statsbus::TenantWindow;
use crate::serve::{Gateway, GatewayConfig, GatewayReport};
use crate::trace::TaskProfile;
use crate::util::json::Json;

/// Index into a [`TenantSet`] (also the `tenant` tag on requests).
pub type TenantId = usize;

/// Ceiling on the per-expert boost factor so SLO pressure prioritizes
/// without drowning the autoscaler's own load signal.
pub const MAX_EXPERT_BOOST: f64 = 3.0;

/// Ceiling on a single tenant's pressure (2.0 = "p95 at 3× its SLO");
/// beyond that, more overshoot carries no extra urgency.
pub const MAX_TENANT_PRESSURE: f64 = 2.0;

/// One tenant's serving contract and demand shape.
#[derive(Debug, Clone)]
pub struct TenantConfig {
    pub name: String,
    /// Weighted-deficit dequeue weight (≥ 1): the tenant's share of each
    /// server's admission bandwidth when every queue is backlogged.
    pub weight: u64,
    /// Latency SLO target in seconds (p95 of arrival→done).
    pub slo_s: f64,
    /// Fraction of each stream's base arrival rate this tenant offers
    /// (before its profile's time modulation).
    pub rate_share: f64,
    /// Arrival profile modulating this tenant's streams.
    pub profile: ArrivalProfile,
    /// Per-(server, tenant) queue bound — the tenant's shed threshold.
    /// A bursting tenant fills *its own* queues and sheds there instead of
    /// crowding every other tenant out of a shared queue.
    pub queue_cap: usize,
    /// Pin every stream of this tenant to one task (so the tenant has a
    /// distinct expert-activation signature); `None` keeps each stream's
    /// own task.
    pub task_override: Option<TaskKind>,
}

impl TenantConfig {
    /// The distinct tasks this tenant's traffic draws from.
    pub fn tasks(&self, workload: &WorkloadConfig) -> Vec<TaskKind> {
        match self.task_override {
            Some(t) => vec![t],
            None => {
                let mut out = Vec::new();
                for s in &workload.streams {
                    if !out.contains(&s.task) {
                        out.push(s.task);
                    }
                }
                out
            }
        }
    }
}

/// The tenants sharing one gateway.
#[derive(Debug, Clone)]
pub struct TenantSet {
    pub tenants: Vec<TenantConfig>,
}

impl TenantSet {
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Admission weights, tenant-indexed.
    pub fn weights(&self) -> Vec<u64> {
        self.tenants.iter().map(|t| t.weight.max(1)).collect()
    }

    /// Per-tenant queue bounds, tenant-indexed.
    pub fn caps(&self) -> Vec<usize> {
        self.tenants.iter().map(|t| t.queue_cap.max(1)).collect()
    }

    /// Per-tenant SLO targets, tenant-indexed.
    pub fn slos(&self) -> Vec<f64> {
        self.tenants.iter().map(|t| t.slo_s).collect()
    }

    /// The bursty two-tenant preset the acceptance comparison runs on: an
    /// *interactive* tenant (steady Poisson, tight SLO, weight 4) sharing
    /// the cluster with a *batch* tenant whose flash crowds (10× rate for
    /// a third of every period) would monopolize a shared queue.
    pub fn pair() -> TenantSet {
        TenantSet {
            tenants: vec![
                TenantConfig {
                    name: "interactive".into(),
                    weight: 4,
                    slo_s: 6.0,
                    rate_share: 0.6,
                    profile: ArrivalProfile::Poisson,
                    queue_cap: 32,
                    task_override: None,
                },
                TenantConfig {
                    name: "batch".into(),
                    weight: 1,
                    slo_s: 30.0,
                    rate_share: 0.9,
                    profile: ArrivalProfile::Bursty {
                        factor: 10.0,
                        burst_s: 40.0,
                        period_s: 120.0,
                    },
                    queue_cap: 32,
                    task_override: Some(TaskKind::Taco),
                },
            ],
        }
    }

    /// Three tenants: the bursty pair plus a diurnal *background* tenant.
    pub fn trio() -> TenantSet {
        let mut set = Self::pair();
        set.tenants.push(TenantConfig {
            name: "background".into(),
            weight: 2,
            slo_s: 15.0,
            rate_share: 0.3,
            profile: ArrivalProfile::Diurnal {
                amplitude: 0.8,
                period_s: 300.0,
            },
            queue_cap: 16,
            task_override: Some(TaskKind::WikiText),
        });
        set
    }

    /// Named presets for the CLI (`--tenants pair|trio`).
    pub fn from_name(s: &str) -> Option<TenantSet> {
        match s {
            "pair" => Some(Self::pair()),
            "trio" => Some(Self::trio()),
            _ => None,
        }
    }
}

/// SLO pressure of one tenant's interval window: relative p95 overshoot
/// plus the window's shed fraction, capped at [`MAX_TENANT_PRESSURE`].
/// 0.0 = the tenant is inside its SLO (nothing to repair).
pub fn window_pressure(w: &TenantWindow, slo_s: f64) -> f64 {
    let mut p = 0.0;
    if w.completed > 0 && slo_s > 0.0 {
        p += (w.p95_s / slo_s - 1.0).max(0.0);
    }
    let offered = w.completed + w.shed;
    if offered > 0 {
        p += w.shed as f64 / offered as f64;
    }
    p.min(MAX_TENANT_PRESSURE)
}

/// Per-eid activation mass of one tenant's tasks (mean over its tasks, so
/// every tenant's mass vector sums to `num_layers` regardless of how many
/// tasks it spans). `mass[l·E + e] ∈ [0, 1]`.
pub fn tenant_expert_mass(
    model: &ModelConfig,
    workload: &WorkloadConfig,
    tenant: &TenantConfig,
) -> Vec<f64> {
    let tasks = tenant.tasks(workload);
    let mut mass = vec![0.0; model.num_layers * model.num_experts];
    if tasks.is_empty() {
        return mass;
    }
    for task in &tasks {
        let prof = TaskProfile::build(*task, model);
        for (l, dist) in prof.dist.iter().enumerate() {
            for (e, &f) in dist.iter().enumerate() {
                mass[l * model.num_experts + e] += f / tasks.len() as f64;
            }
        }
    }
    mass
}

/// Fold per-tenant pressures over precomputed mass vectors into the
/// per-eid boost the autoscaler consumes: `1 + Σ_t pressure_t · mass_t`,
/// clamped to [`MAX_EXPERT_BOOST`]. All-pressure-zero ⇒ all-ones.
pub fn boost_from_masses(
    masses: &[Vec<f64>],
    pressures: &[f64],
) -> Vec<f64> {
    let n = masses.first().map(|m| m.len()).unwrap_or(0);
    let mut boost = vec![1.0; n];
    for (mass, &p) in masses.iter().zip(pressures) {
        if p <= 0.0 {
            continue;
        }
        for (b, &m) in boost.iter_mut().zip(mass) {
            *b += p * m;
        }
    }
    for b in &mut boost {
        *b = b.min(MAX_EXPERT_BOOST);
    }
    boost
}

/// Per-tenant slice of one gateway run (the `tenants` CLI table rows and
/// the `BENCH_tenants.json` metrics).
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub name: String,
    pub weight: u64,
    pub slo_s: f64,
    pub offered: u64,
    pub admitted: u64,
    pub shed: u64,
    pub completed: u64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    /// Completed requests over the tenant's SLO.
    pub violations_completed: u64,
}

impl TenantReport {
    /// SLO attainment over the tenant's offered load: completions within
    /// the SLO / `offered`. Sheds (and anything admitted but never
    /// completed) count against attainment — a request that was never
    /// served did not meet its SLO. 1.0 when idle.
    pub fn attainment(&self) -> f64 {
        if self.offered == 0 {
            1.0
        } else {
            (self.completed - self.violations_completed) as f64
                / self.offered as f64
        }
    }
}

/// The canonical weighted-vs-shared comparison behind the acceptance
/// criterion and `BENCH_tenants.json`: the [`TenantSet::pair`] preset on
/// the trimmed 3-server edge testbed, identical open-loop arrivals on
/// both sides, migration off so the measured gap is pure admission
/// policy. Returns `(weighted, shared_baseline, tenants)`. Deterministic
/// per (seed, horizon) — `tests/tenant_properties.rs` locks the derived
/// metrics JSON byte for byte.
pub fn bursty_comparison(
    seed: u64,
    horizon_s: f64,
) -> (GatewayReport, GatewayReport, TenantSet) {
    let mut model = ModelConfig::mixtral_8x7b_sim();
    model.num_layers = 4;
    let cluster = ClusterConfig::edge_testbed_3_for(&model);
    // 1.25 base req/s per stream: comfortably served off-burst, deeply
    // overloaded while the batch tenant's 10× bursts run — the regime
    // where queue policy decides who pays
    let workload = WorkloadConfig::bigbench(0.8);
    let tenants = TenantSet::pair();
    let run = |shared: bool| {
        let mut gw = Gateway::new(
            &model,
            &cluster,
            &workload,
            uniform::place(&model, &cluster),
            GatewayConfig {
                horizon_s,
                tenants: Some(tenants.clone()),
                shared_queue: shared,
                seed,
                ..GatewayConfig::default()
            },
            CoordinatorConfig {
                interval_s: 30.0,
                migrate: false,
                seed,
                ..CoordinatorConfig::default()
            },
        );
        gw.run()
    };
    (run(false), run(true), tenants)
}

/// Deterministic per-tenant metrics object for `BENCH_tenants.json`:
/// `{mode}_{tenant}_{stat}` keys for both runs plus the constrained
/// (first) tenant's p95 delta. Contains no wall-clock quantities, so the
/// same (seed, horizon) serializes byte-identically across runs.
pub fn comparison_metrics(
    weighted: &GatewayReport,
    shared: &GatewayReport,
) -> Json {
    let mut j = Json::obj();
    for (mode, report) in [("weighted", weighted), ("shared", shared)] {
        for t in &report.tenants {
            let base = format!("{mode}_{}", t.name);
            j.set(&format!("{base}_offered"), Json::Num(t.offered as f64));
            j.set(&format!("{base}_shed"), Json::Num(t.shed as f64));
            j.set(&format!("{base}_p50_s"), Json::Num(t.p50_s));
            j.set(&format!("{base}_p95_s"), Json::Num(t.p95_s));
            j.set(&format!("{base}_p99_s"), Json::Num(t.p99_s));
            j.set(
                &format!("{base}_slo_attainment"),
                Json::Num(t.attainment()),
            );
        }
    }
    if let (Some(w0), Some(s0)) =
        (weighted.tenants.first(), shared.tenants.first())
    {
        j.set(
            "constrained_p95_improvement_s",
            Json::Num(s0.p95_s - w0.p95_s),
        );
    }
    j
}

/// The complete `BENCH_tenants.json` document: suite name + the
/// deterministic metrics, and deliberately **no wall-clock timing block**
/// — so the file is byte-identical across runs at the same (seed,
/// horizon) and CI artifact diffs show only real serving changes. The
/// replay regression test byte-compares exactly this document.
pub fn bench_file_json(
    weighted: &GatewayReport,
    shared: &GatewayReport,
) -> Json {
    Json::from_pairs(vec![
        ("suite", Json::Str("tenants".into())),
        ("metrics", comparison_metrics(weighted, shared)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn presets_are_well_formed() {
        for set in [TenantSet::pair(), TenantSet::trio()] {
            assert!(!set.is_empty());
            assert_eq!(set.weights().len(), set.len());
            assert!(set.weights().iter().all(|&w| w >= 1));
            assert!(set.caps().iter().all(|&c| c >= 1));
            assert!(set.slos().iter().all(|&s| s > 0.0));
            assert!(set
                .tenants
                .iter()
                .all(|t| t.rate_share > 0.0 && t.rate_share <= 1.0));
        }
        assert_eq!(TenantSet::from_name("pair").unwrap().len(), 2);
        assert_eq!(TenantSet::from_name("trio").unwrap().len(), 3);
        assert!(TenantSet::from_name("quartet").is_none());
    }

    #[test]
    fn pressure_zero_inside_slo_and_grows_with_overshoot() {
        let ok = TenantWindow {
            completed: 50,
            violations: 0,
            shed: 0,
            p95_s: 1.0,
        };
        assert_eq!(window_pressure(&ok, 6.0), 0.0);
        let hot = TenantWindow {
            completed: 50,
            violations: 30,
            shed: 0,
            p95_s: 9.0,
        };
        assert!((window_pressure(&hot, 6.0) - 0.5).abs() < 1e-12);
        let shedding = TenantWindow {
            completed: 30,
            violations: 0,
            shed: 10,
            p95_s: 1.0,
        };
        assert!((window_pressure(&shedding, 6.0) - 0.25).abs() < 1e-12);
        // capped: an absurd overshoot saturates
        let melt = TenantWindow {
            completed: 10,
            violations: 10,
            shed: 90,
            p95_s: 1e6,
        };
        assert_eq!(window_pressure(&melt, 1.0), MAX_TENANT_PRESSURE);
        // idle window exerts no pressure
        let idle = TenantWindow::default();
        assert_eq!(window_pressure(&idle, 6.0), 0.0);
    }

    #[test]
    fn masses_and_boost_concentrate_on_tenant_tasks() {
        let mut m = ModelConfig::mixtral_8x7b_sim();
        m.num_layers = 4;
        let w = crate::config::WorkloadConfig::bigbench(1.0);
        let set = TenantSet::pair();
        let masses: Vec<Vec<f64>> = set
            .tenants
            .iter()
            .map(|t| tenant_expert_mass(&m, &w, t))
            .collect();
        for mass in &masses {
            assert_eq!(mass.len(), m.num_layers * m.num_experts);
            let sum: f64 = mass.iter().sum();
            assert!(
                (sum - m.num_layers as f64).abs() < 1e-6,
                "mass sums to num_layers, got {sum}"
            );
        }
        // no pressure ⇒ neutral boost
        let flat = boost_from_masses(&masses, &[0.0, 0.0]);
        assert!(flat.iter().all(|&b| b == 1.0));
        // pressure on tenant 0 boosts its hottest expert the most
        let boost = boost_from_masses(&masses, &[1.0, 0.0]);
        assert!(boost.iter().all(|&b| (1.0..=MAX_EXPERT_BOOST).contains(&b)));
        let hot = masses[0]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        let max_boost =
            boost.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(boost[hot], max_boost);
        assert!(boost[hot] > 1.0);
    }

    #[test]
    fn attainment_counts_sheds_against() {
        let r = TenantReport {
            name: "t".into(),
            weight: 1,
            slo_s: 5.0,
            offered: 100,
            admitted: 80,
            shed: 20,
            completed: 80,
            p50_s: 1.0,
            p95_s: 2.0,
            p99_s: 3.0,
            violations_completed: 10,
        };
        assert!((r.attainment() - 0.7).abs() < 1e-12);
        let idle = TenantReport {
            offered: 0,
            admitted: 0,
            shed: 0,
            completed: 0,
            violations_completed: 0,
            ..r
        };
        assert_eq!(idle.attainment(), 1.0);
    }
}
