//! Synthetic workload generation: task-skewed activation profiles and
//! Poisson request traces.
//!
//! This is the stand-in for the paper's BIG-bench / MMLU-Pro / WikiText /
//! TACO request streams (DESIGN.md §2): the placement problem consumes only
//! per-(server, layer) expert-activation frequencies and token volumes, so a
//! skew-controlled synthetic generator spans the same regime the paper's
//! Figs. 2–3 document — strongly task-dependent, layer-varying skew.

pub mod recorded;
pub mod task;

use crate::config::{ModelConfig, StreamConfig, TaskKind, WorkloadConfig};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::Result;
pub use task::{GateScratch, TaskProfile};

/// Sample a prompt length for `stream`: geometric-ish spread around the
/// mean with a floor of 8 tokens (prompts are never empty). Shared by the
/// offline trace generator and the online gateway's arrival source so
/// their workload distributions cannot silently diverge.
pub fn sample_prompt_tokens(rng: &mut Rng, stream: &StreamConfig) -> usize {
    let spread = rng.range_f64(0.5, 1.5);
    ((stream.mean_prompt_tokens as f64 * spread) as usize).max(8)
}

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: usize,
    /// Home server (where the request arrives; data-locality principle).
    pub server: usize,
    /// Arrival time in virtual seconds.
    pub arrival_s: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    pub task: TaskKind,
    /// Tenant this request belongs to (0 in single-tenant workloads) —
    /// the admission layer keys its per-tenant queues and SLO accounting
    /// off this tag ([`crate::serve::tenant`]).
    pub tenant: usize,
}

/// A generated workload trace, sorted by arrival time.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub requests: Vec<Request>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    pub fn duration(&self) -> f64 {
        self.requests.last().map(|r| r.arrival_s).unwrap_or(0.0)
    }

    pub fn per_server_counts(&self, num_servers: usize) -> Vec<usize> {
        let mut c = vec![0; num_servers];
        for r in &self.requests {
            c[r.server] += 1;
        }
        c
    }

    fn sort(&mut self) {
        self.requests.sort_by(|a, b| {
            a.arrival_s
                .partial_cmp(&b.arrival_s)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        for (i, r) in self.requests.iter_mut().enumerate() {
            r.id = i;
        }
    }

    /// Concatenate: `other`'s arrivals are shifted to start after `self`
    /// ends — the Fig. 7 workload-shift composition.
    pub fn then(mut self, mut other: Trace) -> Trace {
        let offset = self.duration();
        for r in &mut other.requests {
            r.arrival_s += offset;
        }
        self.requests.append(&mut other.requests);
        self.sort();
        self
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.requests
                .iter()
                .map(|r| {
                    Json::from_pairs(vec![
                        ("id", Json::Num(r.id as f64)),
                        ("server", Json::Num(r.server as f64)),
                        ("arrival_s", Json::Num(r.arrival_s)),
                        ("prompt_tokens", Json::Num(r.prompt_tokens as f64)),
                        ("output_tokens", Json::Num(r.output_tokens as f64)),
                        ("task", Json::Str(r.task.name().into())),
                        ("tenant", Json::Num(r.tenant as f64)),
                    ])
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<Trace> {
        let mut requests = Vec::new();
        for r in j.as_arr().unwrap_or(&[]) {
            requests.push(Request {
                id: r.req("id")?.as_usize().unwrap_or(0),
                server: r.req("server")?.as_usize().unwrap_or(0),
                arrival_s: r.req("arrival_s")?.as_f64().unwrap_or(0.0),
                prompt_tokens: r.req("prompt_tokens")?.as_usize().unwrap_or(0),
                output_tokens: r.req("output_tokens")?.as_usize().unwrap_or(0),
                task: TaskKind::from_name(
                    r.req("task")?.as_str().unwrap_or(""),
                )?,
                // absent in pre-multi-tenant traces: default to tenant 0
                tenant: r
                    .get("tenant")
                    .and_then(|t| t.as_usize())
                    .unwrap_or(0),
            });
        }
        Ok(Trace { requests })
    }
}

/// Poisson trace generator over a [`WorkloadConfig`].
pub struct TraceGenerator {
    pub model: ModelConfig,
    pub workload: WorkloadConfig,
    pub seed: u64,
}

impl TraceGenerator {
    pub fn new(
        model: &ModelConfig,
        workload: &WorkloadConfig,
        seed: u64,
    ) -> TraceGenerator {
        TraceGenerator {
            model: model.clone(),
            workload: workload.clone(),
            seed,
        }
    }

    fn gen_stream(
        &self,
        server: usize,
        rng: &mut Rng,
        count: Option<usize>,
        horizon_s: Option<f64>,
    ) -> Vec<Request> {
        let stream = &self.workload.streams[server];
        let rate = 1.0 / stream.mean_interarrival_s;
        let mut t = 0.0;
        let mut out = Vec::new();
        loop {
            t += rng.exponential(rate);
            if let Some(h) = horizon_s {
                if t > h {
                    break;
                }
            }
            if let Some(c) = count {
                if out.len() >= c {
                    break;
                }
            }
            let prompt = sample_prompt_tokens(rng, stream);
            out.push(Request {
                id: 0, // assigned after the global sort
                server,
                arrival_s: t,
                prompt_tokens: prompt,
                output_tokens: stream.output_tokens,
                task: stream.task,
                tenant: 0,
            });
            if count.is_none() && horizon_s.is_none() {
                break; // safety: never loop unboundedly
            }
        }
        out
    }

    fn gen(&self, count: Option<usize>, horizon_s: Option<f64>) -> Trace {
        let mut root = Rng::new(self.seed);
        let mut trace = Trace::default();
        for server in 0..self.workload.streams.len() {
            let mut rng = root.fork(server as u64 + 1);
            trace
                .requests
                .extend(self.gen_stream(server, &mut rng, count, horizon_s));
        }
        trace.sort();
        trace
    }

    /// `n` requests per server (the Fig. 7 "200 requests per server" style).
    pub fn gen_count(&self, n_per_server: usize) -> Trace {
        self.gen(Some(n_per_server), None)
    }

    /// All requests arriving within `[0, horizon_s]` (the Fig. 6 style).
    pub fn gen_until(&self, horizon_s: f64) -> Trace {
        self.gen(None, Some(horizon_s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, WorkloadConfig};

    fn gen() -> TraceGenerator {
        TraceGenerator::new(
            &ModelConfig::mixtral_8x7b_sim(),
            &WorkloadConfig::bigbench(10.0),
            7,
        )
    }

    #[test]
    fn count_mode_exact_per_server() {
        let t = gen().gen_count(50);
        assert_eq!(t.len(), 150);
        assert_eq!(t.per_server_counts(3), vec![50, 50, 50]);
    }

    #[test]
    fn horizon_mode_rate_matches() {
        let t = gen().gen_until(3600.0);
        // 3 servers × 3600 s / 10 s ≈ 1080 requests (±15 %)
        assert!(
            (900..1300).contains(&t.len()),
            "got {} requests",
            t.len()
        );
        assert!(t.duration() <= 3600.0);
    }

    #[test]
    fn sorted_by_arrival_with_sequential_ids() {
        let t = gen().gen_count(30);
        for w in t.requests.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
        for (i, r) in t.requests.iter().enumerate() {
            assert_eq!(r.id, i);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gen().gen_count(20);
        let b = gen().gen_count(20);
        assert_eq!(a.requests, b.requests);
        let c = TraceGenerator::new(
            &ModelConfig::mixtral_8x7b_sim(),
            &WorkloadConfig::bigbench(10.0),
            8,
        )
        .gen_count(20);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn tasks_match_streams() {
        let t = gen().gen_count(10);
        for r in &t.requests {
            let expect = &WorkloadConfig::bigbench(10.0).streams[r.server];
            assert_eq!(r.task, expect.task);
        }
    }

    #[test]
    fn then_shifts_and_merges() {
        let a = gen().gen_count(10);
        let b = TraceGenerator::new(
            &ModelConfig::mixtral_8x7b_sim(),
            &WorkloadConfig::multidata(20.0),
            9,
        )
        .gen_count(10);
        let a_dur = a.duration();
        let merged = a.then(b);
        assert_eq!(merged.len(), 60);
        // the second phase's first arrival is after the first phase's end
        let phase2_start = merged
            .requests
            .iter()
            .filter(|r| r.task.name().starts_with("mmlu")
                || r.task.name() == "wikitext" || r.task.name() == "taco")
            .map(|r| r.arrival_s)
            .fold(f64::INFINITY, f64::min);
        assert!(phase2_start >= a_dur);
    }

    #[test]
    fn json_roundtrip() {
        let t = gen().gen_count(5);
        let back = Trace::from_json(&t.to_json()).unwrap();
        assert_eq!(t.requests, back.requests);
    }

    #[test]
    fn prompt_tokens_positive_and_spread() {
        let t = gen().gen_count(100);
        assert!(t.requests.iter().all(|r| r.prompt_tokens >= 8));
        let min = t.requests.iter().map(|r| r.prompt_tokens).min().unwrap();
        let max = t.requests.iter().map(|r| r.prompt_tokens).max().unwrap();
        assert!(max > min, "prompt lengths should vary");
    }
}
