//! Recorded routing profiles: the paper's simulator (§IV "Simulation
//! Setup") feeds on "operational data collected from DanceMoE — expert
//! selection patterns and token processing volumes". This module converts
//! the activation statistics a serving run accumulated into per-server
//! [`TaskProfile`]s that the engine can replay, and (de)serializes them.

use crate::config::{ModelConfig, TaskKind};
use crate::moe::ActivationStats;
use crate::trace::TaskProfile;
use crate::util::json::Json;
use crate::util::stats::normalize;
use crate::Result;

/// Build one replayable profile per server from observed statistics.
///
/// Layers with no observations fall back to uniform (the replay should not
/// invent skew the run never showed). The `task` tag is a placeholder — a
/// recorded profile is not tied to a named benchmark task.
pub fn profiles_from_stats(
    stats: &ActivationStats,
    model: &ModelConfig,
) -> Vec<TaskProfile> {
    (0..stats.num_servers())
        .map(|n| {
            TaskProfile::from_dist(
                TaskKind::all()[n % TaskKind::all().len()],
                (0..model.num_layers)
                    .map(|l| normalize(&stats.servers[n].freq[l]))
                    .collect(),
            )
        })
        .collect()
}

/// Serialize recorded profiles (for the `dancemoe trace`-style tooling).
pub fn profiles_to_json(profiles: &[TaskProfile]) -> Json {
    Json::Arr(
        profiles
            .iter()
            .map(|p| {
                Json::Arr(p.dist.iter().map(|l| Json::arr_f64(l)).collect())
            })
            .collect(),
    )
}

/// Deserialize recorded profiles.
pub fn profiles_from_json(j: &Json) -> Result<Vec<TaskProfile>> {
    let arr = j.as_arr().unwrap_or(&[]);
    arr.iter()
        .enumerate()
        .map(|(i, p)| {
            let dist = p
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|l| l.to_f64_vec())
                .collect::<Result<Vec<_>>>()?;
            Ok(TaskProfile::from_dist(
                TaskKind::all()[i % TaskKind::all().len()],
                dist,
            ))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn profiles_reflect_observations() {
        let m = ModelConfig::tiny();
        let mut stats = ActivationStats::new(&m, 2);
        stats.record(0, 1, 3, 90.0);
        stats.record(0, 1, 5, 10.0);
        let profiles = profiles_from_stats(&stats, &m);
        assert_eq!(profiles.len(), 2);
        assert!((profiles[0].dist[1][3] - 0.9).abs() < 1e-12);
        assert!((profiles[0].dist[1][5] - 0.1).abs() < 1e-12);
        // unobserved layer falls back to uniform
        assert!((profiles[0].dist[0][0] - 0.125).abs() < 1e-12);
        // server 1 has no observations at all: uniform everywhere
        assert!(profiles[1]
            .dist
            .iter()
            .all(|l| l.iter().all(|&p| (p - 0.125).abs() < 1e-12)));
    }

    #[test]
    fn json_roundtrip() {
        let m = ModelConfig::tiny();
        let mut stats = ActivationStats::new(&m, 3);
        stats.record(2, 0, 7, 5.0);
        stats.record(2, 3, 1, 2.0);
        let profiles = profiles_from_stats(&stats, &m);
        let j = profiles_to_json(&profiles);
        let back = profiles_from_json(&j).unwrap();
        assert_eq!(back.len(), 3);
        for (a, b) in profiles.iter().zip(&back) {
            assert_eq!(a.dist, b.dist);
        }
    }

    #[test]
    fn recorded_profiles_are_sampleable() {
        let m = ModelConfig::tiny();
        let mut stats = ActivationStats::new(&m, 1);
        for e in 0..m.num_experts {
            stats.record(0, 0, e, (e + 1) as f64);
        }
        let profiles = profiles_from_stats(&stats, &m);
        let mut rng = crate::util::rng::Rng::new(1);
        let counts = profiles[0].sample_batch(&mut rng, 0, 50, 2);
        assert_eq!(counts.iter().sum::<u32>(), 100);
    }
}
