//! Task-specific expert-activation profiles (the paper's Fig. 2 / Fig. 3
//! structure, synthesized).
//!
//! Each [`TaskProfile`] holds, for every MoE layer, a probability
//! distribution over that layer's experts. Profiles are deterministic per
//! (task, model): the per-layer skew is drawn from a Dirichlet whose
//! concentration varies by layer — some layers are strongly dominated by a
//! task-specific expert (Fig. 2's "Expert 6 dominates arithmetic"), others
//! are near-uniform (Fig. 3's Layer 1) — reproducing the two observations
//! the paper's placement design builds on:
//!
//! 1. activation patterns are highly task-dependent, and
//! 2. they also vary across layers within a task.

use crate::config::{ModelConfig, TaskKind};
use crate::util::rng::Rng;
use crate::util::stats::entropy_bits;

/// Per-layer concentration schedule: cycles through skew regimes so every
/// task has both dominated and diffuse layers. Offsetting the cycle by the
/// task seed makes the *location* of skewed layers task-dependent too.
const CONCENTRATIONS: [f64; 5] = [0.06, 0.12, 0.35, 1.5, 8.0];

/// Reusable scratch buffers for the gate samplers, so the engine's
/// per-layer-pass sampling allocates nothing in steady state. `counts` is
/// the output of the `*_into` samplers; the other buffers are internals
/// (the working weight/residual vector and the per-token pick list).
#[derive(Debug, Clone, Default)]
pub struct GateScratch {
    /// Dense per-expert token counts — the last `*_into` call's output.
    pub counts: Vec<u32>,
    picked: Vec<usize>,
    wbuf: Vec<f64>,
}

/// A task's activation profile over a model's experts.
#[derive(Debug, Clone)]
pub struct TaskProfile {
    pub task: TaskKind,
    /// `dist[layer][expert]` — probability, rows sum to 1.
    ///
    /// Treated as immutable after construction: the sampler cache below
    /// (`totals`) is derived from it once, so mutating a row directly
    /// would desynchronize it. Build profiles through
    /// [`TaskProfile::build`] or [`TaskProfile::from_dist`].
    pub dist: Vec<Vec<f64>>,
    /// Per-layer `dist[layer].iter().sum::<f64>()`, cached with the same
    /// left-to-right fold so it is bit-identical to the total the
    /// reference sampler recomputes before a token's first draw.
    totals: Vec<f64>,
}

impl TaskProfile {
    /// Wrap an explicit distribution table, building the sampler cache.
    /// Rows are expected to be non-negative (normalization is the
    /// caller's concern — recorded profiles normalize observations).
    pub fn from_dist(task: TaskKind, dist: Vec<Vec<f64>>) -> TaskProfile {
        let totals = dist.iter().map(|row| row.iter().sum()).collect();
        TaskProfile { task, dist, totals }
    }

    /// Build the deterministic profile for `task` on `model`.
    pub fn build(task: TaskKind, model: &ModelConfig) -> TaskProfile {
        let mut rng = Rng::new(task.seed() ^ (model.num_experts as u64) << 32);
        let e = model.num_experts;
        let mut dist = Vec::with_capacity(model.num_layers);
        for layer in 0..model.num_layers {
            let conc_idx =
                (layer + task.seed() as usize) % CONCENTRATIONS.len();
            let conc = CONCENTRATIONS[conc_idx];
            let mut p = rng.dirichlet_sym(conc, e);
            // Give the skewed layers a task-characteristic dominant expert:
            // rotate the heaviest component onto a deterministic slot so
            // different tasks collide on different experts (Fig. 2).
            if conc < 0.5 {
                let dominant =
                    (task.seed() as usize * 7 + layer * 3) % e;
                let heaviest = p
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                p.swap(dominant, heaviest);
            }
            dist.push(p);
        }
        TaskProfile::from_dist(task, dist)
    }

    /// Build all six task profiles for a model.
    pub fn build_all(model: &ModelConfig) -> Vec<TaskProfile> {
        TaskKind::all()
            .into_iter()
            .map(|t| TaskProfile::build(t, model))
            .collect()
    }

    pub fn num_layers(&self) -> usize {
        self.dist.len()
    }

    pub fn num_experts(&self) -> usize {
        self.dist.first().map(|d| d.len()).unwrap_or(0)
    }

    /// Entropy (bits) of the layer's distribution.
    pub fn entropy(&self, layer: usize) -> f64 {
        entropy_bits(&self.dist[layer])
    }

    /// Sample the top-k expert set for one token at `layer`
    /// (k distinct experts, probability-proportional without replacement).
    pub fn sample_token(
        &self,
        rng: &mut Rng,
        layer: usize,
        k: usize,
    ) -> Vec<usize> {
        rng.categorical_k(&self.dist[layer], k)
    }

    /// Sample expert token-counts for a batch of `tokens` tokens at
    /// `layer` with top-`k` routing. Returns a dense count vector of
    /// length `num_experts` summing to `tokens * k`.
    ///
    /// Convenience wrapper over [`TaskProfile::sample_batch_into`]; the
    /// engine's hot path uses the `_into` form with a reused
    /// [`GateScratch`] so steady-state sampling allocates nothing.
    pub fn sample_batch(
        &self,
        rng: &mut Rng,
        layer: usize,
        tokens: usize,
        k: usize,
    ) -> Vec<u32> {
        let mut scratch = GateScratch::default();
        self.sample_batch_into(rng, layer, tokens, k, &mut scratch);
        scratch.counts
    }

    /// Allocation-free form of [`TaskProfile::sample_batch`]: fills
    /// `scratch.counts` (cleared and resized to `num_experts`).
    ///
    /// Performs the reference sampler's **exact** decision procedure —
    /// same uniform stream (one `rng.f64()` per draw, none on the
    /// degenerate path), same fold order, same subtract-scan crossing —
    /// with its overheads removed: the per-call `dist` clone becomes a
    /// reused-buffer copy, the token's first draw uses the cached layer
    /// total (bit-identical: the working weights equal `dist` at token
    /// start), and the reference's three O(E) passes per draw (degeneracy
    /// sum, categorical's own sum, the scan) fuse into at most one sum
    /// plus one scan.
    ///
    /// Deliberately **not** a CDF binary search: a prototyped
    /// O(log E) draw over cached prefix sums with incrementally-maintained
    /// remaining mass diverges from the reference stream under
    /// catastrophic cancellation — the Dirichlet(0.06) profile layers mix
    /// weights spanning ~20 orders of magnitude, where `total − Σpicked`
    /// is rounding residue rather than the true remaining mass (fuzzing
    /// found divergent picks at ~4% of trials, including duplicate picks
    /// where the adjusted prefix lost monotonicity). Byte-identical
    /// replay is the contract (`tests/hotpath_determinism.rs`), so the
    /// scan stays; with E ≤ 64 it is a handful of adds per draw, and the
    /// removed allocations were the actual hot-path cost.
    pub fn sample_batch_into(
        &self,
        rng: &mut Rng,
        layer: usize,
        tokens: usize,
        k: usize,
        scratch: &mut GateScratch,
    ) {
        let e = self.num_experts();
        let k = k.min(e);
        scratch.counts.clear();
        scratch.counts.resize(e, 0);
        if tokens == 0 || k == 0 {
            return;
        }
        let dist = &self.dist[layer];
        let full_total = self.totals[layer];
        scratch.wbuf.clear();
        scratch.wbuf.extend_from_slice(dist);
        let w = &mut scratch.wbuf;
        let picked = &mut scratch.picked;
        for _ in 0..tokens {
            picked.clear();
            for d in 0..k {
                // the reference recomputes Σw before every draw; at a
                // token's first draw w == dist, so the cached layer total
                // is the same fold bit-for-bit
                let total = if d == 0 {
                    full_total
                } else {
                    w.iter().sum::<f64>()
                };
                if total <= 0.0 {
                    // degenerate: fill with unused indices deterministically
                    for j in 0..e {
                        if picked.len() == k {
                            break;
                        }
                        if !picked.contains(&j) {
                            picked.push(j);
                        }
                    }
                    break;
                }
                // fused categorical draw: the same subtract-scan the
                // reference's `rng.categorical` performs
                let mut u = rng.f64() * total;
                let mut idx = e - 1;
                for (i, &wi) in w.iter().enumerate() {
                    u -= wi;
                    if u <= 0.0 {
                        idx = i;
                        break;
                    }
                }
                picked.push(idx);
                w[idx] = 0.0;
            }
            for &idx in picked.iter() {
                scratch.counts[idx] += 1;
                w[idx] = dist[idx];
            }
        }
    }

    /// Fast batch routing for large prefill batches: expected counts with a
    /// stochastically-allocated remainder (O(E) instead of O(tokens·k·E)).
    /// Preserves the total mass `tokens · k` and the per-expert cap of
    /// `tokens` (a token can use an expert at most once).
    pub fn sample_batch_fast(
        &self,
        rng: &mut Rng,
        layer: usize,
        tokens: usize,
        k: usize,
    ) -> Vec<u32> {
        let mut scratch = GateScratch::default();
        self.sample_batch_fast_into(rng, layer, tokens, k, &mut scratch);
        scratch.counts
    }

    /// Allocation-free form of [`TaskProfile::sample_batch_fast`] (same
    /// algorithm and RNG stream; the count and residual buffers live in
    /// `scratch`).
    pub fn sample_batch_fast_into(
        &self,
        rng: &mut Rng,
        layer: usize,
        tokens: usize,
        k: usize,
        scratch: &mut GateScratch,
    ) {
        let e = self.num_experts();
        let k = k.min(e);
        let target = (tokens * k) as u32;
        let dist = &self.dist[layer];
        scratch.counts.clear();
        scratch.counts.resize(e, 0);
        scratch.wbuf.clear();
        scratch.wbuf.resize(e, 0.0);
        let counts = &mut scratch.counts;
        let residual = &mut scratch.wbuf;
        let mut placed: u32 = 0;
        for i in 0..e {
            let exact = (k as f64 * dist[i] * tokens as f64)
                .min(tokens as f64);
            let fl = exact.floor();
            counts[i] = fl as u32;
            residual[i] = exact - fl;
            placed += counts[i];
        }
        // allocate the remainder by residual weight, respecting the cap
        while placed < target {
            if residual.iter().sum::<f64>() <= 0.0 {
                // caps ate the residuals: spill uniformly over non-full
                let open: Vec<usize> = (0..e)
                    .filter(|&i| counts[i] < tokens as u32)
                    .collect();
                if open.is_empty() {
                    break;
                }
                let i = *rng.choose(&open);
                counts[i] += 1;
                placed += 1;
                continue;
            }
            let i = rng.categorical(residual);
            if counts[i] < tokens as u32 {
                counts[i] += 1;
                placed += 1;
            }
            residual[i] = 0.0;
        }
    }

    /// Expected (non-sampled) batch counts — used by the fast analytic path
    /// of the Fig. 8 scaling simulator where per-token sampling at 256 GPUs
    /// would dominate runtime.
    pub fn expected_batch(
        &self,
        layer: usize,
        tokens: usize,
        k: usize,
    ) -> Vec<f64> {
        // Expected tokens per expert under k draws w/o replacement is
        // approximated by k·p_e·T (exact for k=1; good for k ≪ E).
        self.dist[layer]
            .iter()
            .map(|p| (k as f64 * p * tokens as f64).min(tokens as f64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn model() -> ModelConfig {
        ModelConfig::mixtral_8x7b_sim()
    }

    #[test]
    fn profile_rows_are_distributions() {
        for t in TaskKind::all() {
            let p = TaskProfile::build(t, &model());
            assert_eq!(p.num_layers(), 32);
            assert_eq!(p.num_experts(), 8);
            for l in 0..p.num_layers() {
                let sum: f64 = p.dist[l].iter().sum();
                assert!((sum - 1.0).abs() < 1e-9, "{t:?} layer {l}");
                assert!(p.dist[l].iter().all(|&x| x >= 0.0));
            }
        }
    }

    #[test]
    fn profiles_deterministic_and_task_dependent() {
        let a = TaskProfile::build(TaskKind::Arithmetic, &model());
        let b = TaskProfile::build(TaskKind::Arithmetic, &model());
        let c = TaskProfile::build(TaskKind::AsciiRecognition, &model());
        assert_eq!(a.dist, b.dist);
        assert_ne!(a.dist, c.dist);
    }

    #[test]
    fn entropy_varies_across_layers_fig3() {
        // Fig. 3: some layers strongly skewed, others near-uniform.
        let p = TaskProfile::build(TaskKind::Arithmetic, &model());
        let ents: Vec<f64> = (0..p.num_layers()).map(|l| p.entropy(l)).collect();
        let min = ents.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = ents.iter().cloned().fold(f64::MIN, f64::max);
        assert!(min < 1.5, "expected a skewed layer, min entropy {min}");
        assert!(max > 2.5, "expected a diffuse layer, max entropy {max}");
    }

    #[test]
    fn dominant_experts_differ_between_tasks_fig2() {
        // Fig. 2: at a skewed layer, different tasks favour different experts.
        let m = model();
        let a = TaskProfile::build(TaskKind::Arithmetic, &m);
        let b = TaskProfile::build(TaskKind::AsciiRecognition, &m);
        let mut differs = 0;
        for l in 0..m.num_layers {
            if a.entropy(l) < 1.5 && b.entropy(l) < 1.5 {
                let am = crate::util::stats::argsort_desc(&a.dist[l])[0];
                let bm = crate::util::stats::argsort_desc(&b.dist[l])[0];
                if am != bm {
                    differs += 1;
                }
            }
        }
        assert!(differs > 0, "no layer where dominant experts differ");
    }

    #[test]
    fn sample_batch_counts_sum() {
        let p = TaskProfile::build(TaskKind::Taco, &model());
        let mut rng = Rng::new(3);
        let counts = p.sample_batch(&mut rng, 0, 100, 2);
        assert_eq!(counts.iter().sum::<u32>(), 200);
        assert_eq!(counts.len(), 8);
    }

    #[test]
    fn sample_batch_tracks_distribution() {
        let p = TaskProfile::build(TaskKind::Arithmetic, &model());
        let mut rng = Rng::new(5);
        // find a skewed layer and check the dominant expert gets the most
        let l = (0..p.num_layers())
            .min_by(|&a, &b| p.entropy(a).partial_cmp(&p.entropy(b)).unwrap())
            .unwrap();
        let counts = p.sample_batch(&mut rng, l, 2000, 1);
        let sampled_max = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i)
            .unwrap();
        let true_max = crate::util::stats::argsort_desc(&p.dist[l])[0];
        assert_eq!(sampled_max, true_max);
    }

    #[test]
    fn sample_batch_fast_mass_and_caps() {
        let m = ModelConfig::deepseek_v2_lite_sim();
        let p = TaskProfile::build(TaskKind::MmluPro, &m);
        let mut rng = Rng::new(9);
        for (tokens, k) in [(100usize, 8usize), (37, 8), (16, 1)] {
            let counts = p.sample_batch_fast(&mut rng, 0, tokens, k);
            let total: u32 = counts.iter().sum();
            assert_eq!(total, (tokens * k) as u32, "t{tokens} k{k}");
            assert!(counts.iter().all(|&c| c <= tokens as u32));
        }
    }

    #[test]
    fn sample_batch_fast_tracks_distribution() {
        let m = ModelConfig::mixtral_8x7b_sim();
        let p = TaskProfile::build(TaskKind::Arithmetic, &m);
        let l = (0..p.num_layers())
            .min_by(|&a, &b| p.entropy(a).partial_cmp(&p.entropy(b)).unwrap())
            .unwrap();
        let mut rng = Rng::new(10);
        let counts = p.sample_batch_fast(&mut rng, l, 4000, 1);
        let sampled_max = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i)
            .unwrap();
        assert_eq!(
            sampled_max,
            crate::util::stats::argsort_desc(&p.dist[l])[0]
        );
    }

    #[test]
    fn expected_batch_matches_mass() {
        let p = TaskProfile::build(TaskKind::WikiText, &model());
        let exp = p.expected_batch(0, 100, 2);
        let total: f64 = exp.iter().sum();
        // ≈ tokens*k (can undershoot slightly due to the per-expert cap)
        assert!(total <= 200.0 + 1e-9);
        assert!(total > 150.0);
    }

    #[test]
    fn deepseek_topology_profiles() {
        let m = ModelConfig::deepseek_v2_lite_sim();
        let p = TaskProfile::build(TaskKind::MmluPro, &m);
        assert_eq!(p.num_layers(), 26);
        assert_eq!(p.num_experts(), 64);
        let mut rng = Rng::new(1);
        let sel = p.sample_token(&mut rng, 0, 8);
        assert_eq!(sel.len(), 8);
        let mut s = sel.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8, "top-8 must be distinct");
    }
}
