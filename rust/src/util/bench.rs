//! Mini benchmark harness (replaces `criterion`, unavailable offline).
//!
//! Drives the `cargo bench` targets (`harness = false` in Cargo.toml):
//! warmup, adaptive iteration count, mean/p50/p99 per benchmark, aligned
//! report output. Benchmarks of whole experiments (one per paper table /
//! figure) use `run_once` mode — they are minutes-of-virtual-time
//! simulations whose *output rows* are the deliverable; micro-benchmarks of
//! the hot path use the timed mode.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub total: Duration,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        if self.mean_ns == 0.0 {
            0.0
        } else {
            items_per_iter / (self.mean_ns * 1e-9)
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark runner: collects results and prints a report on drop.
pub struct Bencher {
    pub suite: String,
    pub results: Vec<BenchResult>,
    /// target measurement time per benchmark
    pub budget: Duration,
    pub warmup: Duration,
}

impl Bencher {
    pub fn new(suite: &str) -> Bencher {
        // Allow CI-style overrides: DANCEMOE_BENCH_MS per-bench budget.
        let ms = std::env::var("DANCEMOE_BENCH_MS")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .unwrap_or(1500);
        println!("\n== bench suite: {suite} ==");
        Bencher {
            suite: suite.to_string(),
            results: Vec::new(),
            budget: Duration::from_millis(ms),
            warmup: Duration::from_millis(ms / 5),
        }
    }

    /// Timed micro/meso benchmark: runs `f` repeatedly within the budget.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // measure
        let mut samples: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.budget {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len().max(1);
        let mean = samples.iter().sum::<f64>() / n as f64;
        let p50 = samples[n / 2.min(n - 1)];
        let p99 = samples[((n as f64 * 0.99) as usize).min(n - 1)];
        let res = BenchResult {
            name: name.to_string(),
            iters: n as u64,
            mean_ns: mean,
            p50_ns: p50,
            p99_ns: p99,
            total: t0.elapsed(),
        };
        println!(
            "  {:<44} {:>12}/iter  p50 {:>12}  p99 {:>12}  ({} iters)",
            res.name,
            fmt_ns(res.mean_ns),
            fmt_ns(res.p50_ns),
            fmt_ns(res.p99_ns),
            res.iters
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Run-once benchmark for whole experiments: time a single execution and
    /// report it (the experiment's own printed rows are the real output).
    pub fn run_once<F: FnOnce()>(&mut self, name: &str, f: F) -> &BenchResult {
        let t0 = Instant::now();
        f();
        let ns = t0.elapsed().as_nanos() as f64;
        let res = BenchResult {
            name: name.to_string(),
            iters: 1,
            mean_ns: ns,
            p50_ns: ns,
            p99_ns: ns,
            total: t0.elapsed(),
        };
        println!("  {:<44} {:>12} (1 run)", res.name, fmt_ns(ns));
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Prevent the optimizer from discarding a computed value.
    pub fn black_box<T>(x: T) -> T {
        std::hint::black_box(x)
    }

    /// Write a machine-readable bench report (`BENCH_<suite>.json` by
    /// convention): the suite name, every benchmark's timing stats, and a
    /// caller-supplied `metrics` object (latency percentiles, shed rate,
    /// replica counts, ...) so the perf trajectory can be tracked across
    /// PRs by diffing files instead of scraping stdout.
    pub fn write_json(
        &self,
        path: &std::path::Path,
        metrics: crate::util::json::Json,
    ) -> crate::Result<()> {
        use crate::util::json::Json;
        let results = Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    Json::from_pairs(vec![
                        ("name", Json::Str(r.name.clone())),
                        ("iters", Json::Num(r.iters as f64)),
                        ("mean_ns", Json::Num(r.mean_ns)),
                        ("p50_ns", Json::Num(r.p50_ns)),
                        ("p99_ns", Json::Num(r.p99_ns)),
                    ])
                })
                .collect(),
        );
        let j = Json::from_pairs(vec![
            ("suite", Json::Str(self.suite.clone())),
            ("results", results),
            ("metrics", metrics),
        ]);
        j.write_file(path)
    }
}

impl Drop for Bencher {
    fn drop(&mut self) {
        println!(
            "== suite {} done: {} benchmarks ==\n",
            self.suite,
            self.results.len()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("DANCEMOE_BENCH_MS", "30");
        let mut b = Bencher::new("selftest");
        let r = b
            .bench("noop-ish", || {
                let v: u64 = Bencher::black_box((0..50u64).sum());
                assert!(v > 0);
            })
            .clone();
        assert!(r.iters > 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns * 1.0001);
        std::env::remove_var("DANCEMOE_BENCH_MS");
    }

    #[test]
    fn run_once_records_single_iter() {
        std::env::set_var("DANCEMOE_BENCH_MS", "30");
        let mut b = Bencher::new("selftest2");
        let r = b.run_once("one", || std::thread::sleep(
            Duration::from_millis(2),
        ));
        assert_eq!(r.iters, 1);
        assert!(r.mean_ns >= 2e6 * 0.5);
        std::env::remove_var("DANCEMOE_BENCH_MS");
    }

    #[test]
    fn write_json_roundtrips() {
        use crate::util::json::Json;
        std::env::set_var("DANCEMOE_BENCH_MS", "20");
        let mut b = Bencher::new("jsontest");
        b.run_once("one", || {});
        let dir = std::env::temp_dir();
        let path = dir.join("dancemoe_bench_selftest.json");
        let metrics = Json::from_pairs(vec![
            ("p95_s", Json::Num(1.25)),
            ("shed_rate", Json::Num(0.0)),
        ]);
        b.write_json(&path, metrics).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(
            j.get("suite").and_then(|s| s.as_str()),
            Some("jsontest")
        );
        assert_eq!(
            j.get("metrics")
                .and_then(|m| m.get("p95_s"))
                .and_then(|v| v.as_f64()),
            Some(1.25)
        );
        assert_eq!(
            j.get("results").and_then(|r| r.as_arr()).map(|a| a.len()),
            Some(1)
        );
        let _ = std::fs::remove_file(&path);
        std::env::remove_var("DANCEMOE_BENCH_MS");
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            p50_ns: 1e9,
            p99_ns: 1e9,
            total: Duration::from_secs(1),
        };
        assert!((r.throughput(1000.0) - 1000.0).abs() < 1e-9);
    }
}
