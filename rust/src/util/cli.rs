//! Declarative command-line parsing (replaces `clap`, unavailable offline).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! defaults, required flags, and auto-generated `--help` text.

use std::collections::BTreeMap;

/// One flag specification.
#[derive(Debug, Clone)]
pub struct Flag {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
    pub required: bool,
}

/// A parsed invocation: flag values + positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str) -> String {
        self.values.get(name).cloned().unwrap_or_default()
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.values
            .get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.values
            .get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, String> {
        self.values
            .get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }
}

/// One subcommand: name, help, flags.
pub struct Command {
    pub name: &'static str,
    pub help: &'static str,
    pub flags: Vec<Flag>,
}

impl Command {
    pub fn new(name: &'static str, help: &'static str) -> Command {
        Command {
            name,
            help,
            flags: Vec::new(),
        }
    }

    pub fn flag(
        mut self,
        name: &'static str,
        default: Option<&'static str>,
        help: &'static str,
    ) -> Command {
        self.flags.push(Flag {
            name,
            help,
            default,
            is_switch: false,
            required: default.is_none(),
        });
        self
    }

    pub fn opt_flag(
        mut self,
        name: &'static str,
        help: &'static str,
    ) -> Command {
        self.flags.push(Flag {
            name,
            help,
            default: None,
            is_switch: false,
            required: false,
        });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Command {
        self.flags.push(Flag {
            name,
            help,
            default: None,
            is_switch: true,
            required: false,
        });
        self
    }

    /// Parse this command's arguments (after the subcommand token).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        for f in &self.flags {
            if let Some(d) = f.default {
                out.values.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}"))?;
                if spec.is_switch {
                    if inline.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    out.switches.insert(name.to_string(), true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(|| {
                                    format!("--{name} needs a value")
                                })?
                                .clone()
                        }
                    };
                    out.values.insert(name.to_string(), v);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        for f in &self.flags {
            if f.required && !f.is_switch && !out.values.contains_key(f.name)
            {
                return Err(format!("missing required flag --{}", f.name));
            }
        }
        Ok(out)
    }

    pub fn usage(&self) -> String {
        let mut s = format!("  {} — {}\n", self.name, self.help);
        for f in &self.flags {
            let kind = if f.is_switch { "" } else { " <value>" };
            let def = match f.default {
                Some(d) => format!(" (default: {d})"),
                None if f.required => " (required)".to_string(),
                None => String::new(),
            };
            s.push_str(&format!(
                "      --{}{kind}  {}{def}\n",
                f.name, f.help
            ));
        }
        s
    }
}

/// A CLI: program name + subcommands.
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl Cli {
    /// Dispatch on argv\[1\]; returns (command name, parsed args) or a
    /// usage/error string (Err(msg) with exit intent).
    pub fn dispatch(&self, argv: &[String]) -> Result<(String, Args), String> {
        let sub = argv.get(1).map(|s| s.as_str()).unwrap_or("help");
        if sub == "help" || sub == "--help" || sub == "-h" {
            return Err(self.usage());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == sub)
            .ok_or_else(|| {
                format!("unknown command '{sub}'\n\n{}", self.usage())
            })?;
        if argv.iter().any(|a| a == "--help" || a == "-h") {
            return Err(cmd.usage());
        }
        let args = cmd.parse(&argv[2..]).map_err(|e| {
            format!("{}: {e}\n\n{}", cmd.name, cmd.usage())
        })?;
        Ok((sub.to_string(), args))
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nCommands:\n", self.program, self.about);
        for c in &self.commands {
            s.push_str(&c.usage());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("serve", "run the server")
            .flag("model", Some("tiny"), "model preset")
            .flag("requests", None, "number of requests")
            .switch("verbose", "chatty output")
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_defaults() {
        let a = cmd().parse(&sv(&["--requests", "10"])).unwrap();
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.get_usize("requests").unwrap(), 10);
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn parses_equals_form_and_switch() {
        let a = cmd()
            .parse(&sv(&["--requests=5", "--model=big", "--verbose"]))
            .unwrap();
        assert_eq!(a.get("model"), Some("big"));
        assert!(a.switch("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        let e = cmd().parse(&sv(&[])).unwrap_err();
        assert!(e.contains("requests"));
    }

    #[test]
    fn unknown_flag_errors() {
        let e = cmd().parse(&sv(&["--nope", "1"])).unwrap_err();
        assert!(e.contains("nope"));
    }

    #[test]
    fn switch_with_value_errors() {
        let e = cmd()
            .parse(&sv(&["--verbose=1", "--requests", "1"]))
            .unwrap_err();
        assert!(e.contains("verbose"));
    }

    #[test]
    fn positional_passthrough() {
        let a = cmd()
            .parse(&sv(&["--requests", "1", "extra1", "extra2"]))
            .unwrap();
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn dispatch_selects_command() {
        let cli = Cli {
            program: "dancemoe",
            about: "test",
            commands: vec![cmd()],
        };
        let (name, args) = cli
            .dispatch(&sv(&["dancemoe", "serve", "--requests", "3"]))
            .unwrap();
        assert_eq!(name, "serve");
        assert_eq!(args.get_usize("requests").unwrap(), 3);
        assert!(cli.dispatch(&sv(&["dancemoe", "nope"])).is_err());
        assert!(cli.dispatch(&sv(&["dancemoe"])).is_err()); // help
    }
}
