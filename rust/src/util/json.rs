//! Minimal-but-complete JSON parser and serializer.
//!
//! The offline build environment has no `serde`, so configs, traces, the
//! AOT artifact manifest and the cross-language test vectors all go through
//! this module. It implements RFC 8259 JSON: objects, arrays, strings with
//! escapes (incl. `\uXXXX` surrogate pairs), numbers, booleans, null.
//!
//! Numbers are held as `f64` (adequate for every payload we exchange; the
//! artifact manifest's largest integers are byte counts well under 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap so serialization is deterministic (stable key order).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required config fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key '{key}'")))
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten a numeric array into `Vec<f64>`.
    pub fn to_f64_vec(&self) -> Result<Vec<f64>> {
        let arr = self
            .as_arr()
            .ok_or_else(|| Error::Json("expected array".into()))?;
        arr.iter()
            .map(|x| {
                x.as_f64()
                    .ok_or_else(|| Error::Json("expected number".into()))
            })
            .collect()
    }

    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.to_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    pub fn to_usize_vec(&self) -> Result<Vec<usize>> {
        Ok(self
            .to_f64_vec()?
            .into_iter()
            .map(|x| x as usize)
            .collect())
    }

    // ---- io -------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!(
                "trailing garbage at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    pub fn read_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)?;
        Json::parse(&text)
    }

    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string())?;
        Ok(())
    }

    /// Pretty serialization with 1-space indent (matches aot.py's output
    /// style; used for human-inspected configs and reports).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    for _ in 0..=depth {
                        out.push(' ');
                    }
                    x.write_pretty(out, depth + 1);
                }
                out.push('\n');
                for _ in 0..depth {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    for _ in 0..=depth {
                        out.push(' ');
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    x.write_pretty(out, depth + 1);
                }
                out.push('\n');
                for _ in 0..depth {
                    out.push(' ');
                }
                out.push('}');
            }
            other => {
                let _ = write!(out, "{other}");
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                let mut out = String::with_capacity(s.len() + 2);
                write_escaped(&mut out, s);
                f.write_str(&out)
            }
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    let mut key = String::new();
                    write_escaped(&mut key, k);
                    write!(f, "{key}:{x}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    0x10000
                                        + ((hi - 0xD800) << 10)
                                        + (lo - 0xDC00)
                                } else {
                                    return Err(
                                        self.err("lone high surrogate")
                                    );
                                }
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            continue; // pos already past the escape
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // multi-byte UTF-8 passthrough
                    let start = self.pos;
                    let s = &self.bytes[start..];
                    let len = utf8_len(s[0]);
                    if s.len() < len {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&s[..len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.bytes.len() < self.pos + 4 {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16)
            .map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::Str("hi\n".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let cases = [
            r#"{"a":[1,2.5,-3],"b":"x\"y","c":null,"d":true}"#,
            "[[],[[]],{}]",
            "12345678901234",
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "case {c}");
        }
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":[{"d":1}]}}"#).unwrap();
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn float_formatting_preserves_precision() {
        let v = Json::Num(0.1234567890123);
        let back = Json::parse(&v.to_string()).unwrap();
        assert!((back.as_f64().unwrap() - 0.1234567890123).abs() < 1e-15);
    }

    #[test]
    fn vec_helpers() {
        let v = Json::parse("[1,2,3]").unwrap();
        assert_eq!(v.to_usize_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.to_f32_vec().unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(Json::parse("[1,\"x\"]").unwrap().to_f64_vec().is_err());
    }

    #[test]
    fn req_reports_key() {
        let v = Json::obj();
        let err = v.req("bandwidth").unwrap_err().to_string();
        assert!(err.contains("bandwidth"));
    }
}
