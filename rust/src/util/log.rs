//! Minimal leveled logger (replaces `log`/`tracing`, offline environment).
//!
//! The paper's system "logs gating decisions [and] expert invocation costs
//! ... reported to the Global Scheduler" (§III-A); this substrate carries
//! that observability stream. Levels are filtered by the `DANCEMOE_LOG`
//! environment variable (`error|warn|info|debug`, default `warn`) and
//! records can be captured in-memory for tests.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }

    fn from_env() -> Level {
        match std::env::var("DANCEMOE_LOG")
            .unwrap_or_default()
            .to_ascii_lowercase()
            .as_str()
        {
            "error" => Level::Error,
            "info" => Level::Info,
            "debug" => Level::Debug,
            _ => Level::Warn,
        }
    }
}

static THRESHOLD: AtomicU8 = AtomicU8::new(u8::MAX); // unset sentinel
static CAPTURE: Mutex<Option<Vec<String>>> = Mutex::new(None);

fn threshold() -> Level {
    let raw = THRESHOLD.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let lvl = Level::from_env();
        THRESHOLD.store(lvl as u8, Ordering::Relaxed);
        lvl
    } else {
        match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

/// Override the level programmatically (tests, CLI `--verbose`).
pub fn set_level(level: Level) {
    THRESHOLD.store(level as u8, Ordering::Relaxed);
}

/// Begin capturing records in memory (tests); returns previous capture.
pub fn capture_start() {
    *CAPTURE.lock().unwrap() = Some(Vec::new());
}

/// Stop capturing and return the captured records.
pub fn capture_take() -> Vec<String> {
    CAPTURE.lock().unwrap().take().unwrap_or_default()
}

/// Emit a record at `level` under a `target` tag.
pub fn log(level: Level, target: &str, msg: &str) {
    if level > threshold() {
        return;
    }
    let line = format!("[{:<5} {target}] {msg}", level.name());
    let mut cap = CAPTURE.lock().unwrap();
    match cap.as_mut() {
        Some(buf) => buf.push(line),
        None => eprintln!("{line}"),
    }
}

pub fn warn(target: &str, msg: &str) {
    log(Level::Warn, target, msg);
}

pub fn info(target: &str, msg: &str) {
    log(Level::Info, target, msg);
}

pub fn debug(target: &str, msg: &str) {
    log(Level::Debug, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_filter_and_capture() {
        set_level(Level::Info);
        capture_start();
        info("test", "hello");
        debug("test", "hidden");
        warn("test", "warned");
        let got = capture_take();
        assert_eq!(got.len(), 2);
        assert!(got[0].contains("INFO"));
        assert!(got[0].contains("hello"));
        assert!(got[1].contains("warned"));
        set_level(Level::Warn);
    }

    #[test]
    fn error_always_passes() {
        set_level(Level::Error);
        capture_start();
        log(Level::Error, "x", "boom");
        warn("x", "quiet");
        let got = capture_take();
        assert_eq!(got.len(), 1);
        assert!(got[0].contains("boom"));
        set_level(Level::Warn);
    }
}
