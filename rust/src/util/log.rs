//! Minimal leveled logger (replaces `log`/`tracing`, offline environment).
//!
//! The paper's system "logs gating decisions [and] expert invocation costs
//! ... reported to the Global Scheduler" (§III-A); this substrate carries
//! that observability stream. Levels are filtered by the `DANCEMOE_LOG`
//! environment variable (`error|warn|info|debug`, default `warn`) and
//! records can be captured in-memory for tests.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }

    fn from_env() -> Level {
        match std::env::var("DANCEMOE_LOG")
            .unwrap_or_default()
            .to_ascii_lowercase()
            .as_str()
        {
            "error" => Level::Error,
            "info" => Level::Info,
            "debug" => Level::Debug,
            _ => Level::Warn,
        }
    }
}

/// `u8::MAX` = "unset" — the next [`log`] call reads `DANCEMOE_LOG`.
static THRESHOLD: AtomicU8 = AtomicU8::new(u8::MAX);
static CAPTURE: Mutex<Option<Vec<String>>> = Mutex::new(None);
/// Serializes capture sessions so parallel tests cannot interleave
/// records or clobber each other's threshold.
static CAPTURE_GATE: Mutex<()> = Mutex::new(());

fn threshold() -> Level {
    let raw = THRESHOLD.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let lvl = Level::from_env();
        // another thread may race this store with the same env-derived
        // value, or with an explicit `set_level` — last writer wins,
        // which `reset_for_test` can always undo
        THRESHOLD.store(lvl as u8, Ordering::Relaxed);
        lvl
    } else {
        match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

/// Override the level programmatically (tests, CLI `--verbose`).
pub fn set_level(level: Level) {
    THRESHOLD.store(level as u8, Ordering::Relaxed);
}

/// Drop the cached threshold so the next record re-reads `DANCEMOE_LOG`.
/// Without this the first `log` call pins the level for the whole
/// process and later env changes are silently ignored.
pub fn reset_for_test() {
    THRESHOLD.store(u8::MAX, Ordering::Relaxed);
}

fn lock_gate() -> std::sync::MutexGuard<'static, ()> {
    // a panicking capture test must not wedge every later one
    CAPTURE_GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// In-memory capture session for tests. Holding the guard serializes
/// concurrent captures (parallel `cargo test` threads queue instead of
/// mixing records); dropping it restores the prior threshold and stops
/// capturing, even on panic.
pub struct Capture {
    prev_raw: u8,
    _gate: std::sync::MutexGuard<'static, ()>,
}

/// Begin capturing records at `level`; returns the session guard.
pub fn capture_at(level: Level) -> Capture {
    let gate = lock_gate();
    let prev_raw = THRESHOLD.swap(level as u8, Ordering::Relaxed);
    *CAPTURE.lock().unwrap_or_else(|e| e.into_inner()) = Some(Vec::new());
    Capture {
        prev_raw,
        _gate: gate,
    }
}

impl Capture {
    /// Drain the records captured so far.
    pub fn take(&mut self) -> Vec<String> {
        CAPTURE
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .replace(Vec::new())
            .unwrap_or_default()
    }
}

impl Drop for Capture {
    fn drop(&mut self) {
        *CAPTURE.lock().unwrap_or_else(|e| e.into_inner()) = None;
        THRESHOLD.store(self.prev_raw, Ordering::Relaxed);
    }
}

/// Emit a record at `level` under a `target` tag.
pub fn log(level: Level, target: &str, msg: &str) {
    if level > threshold() {
        return;
    }
    let line = format!("[{:<5} {target}] {msg}", level.name());
    let mut cap = CAPTURE.lock().unwrap();
    match cap.as_mut() {
        Some(buf) => buf.push(line),
        None => eprintln!("{line}"),
    }
}

pub fn warn(target: &str, msg: &str) {
    log(Level::Warn, target, msg);
}

pub fn info(target: &str, msg: &str) {
    log(Level::Info, target, msg);
}

pub fn debug(target: &str, msg: &str) {
    log(Level::Debug, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_filter_and_capture() {
        let mut cap = capture_at(Level::Info);
        info("test", "hello");
        debug("test", "hidden");
        warn("test", "warned");
        let got = cap.take();
        assert_eq!(got.len(), 2);
        assert!(got[0].contains("INFO"));
        assert!(got[0].contains("hello"));
        assert!(got[1].contains("warned"));
    }

    #[test]
    fn error_always_passes() {
        let mut cap = capture_at(Level::Error);
        log(Level::Error, "x", "boom");
        warn("x", "quiet");
        let got = cap.take();
        assert_eq!(got.len(), 1);
        assert!(got[0].contains("boom"));
    }

    #[test]
    fn capture_guard_restores_threshold_on_drop() {
        set_level(Level::Warn);
        {
            let mut cap = capture_at(Level::Debug);
            debug("t", "seen");
            assert_eq!(cap.take().len(), 1);
        }
        // back to Warn, and no longer capturing
        let mut cap = capture_at(Level::Warn);
        debug("t", "hidden again");
        assert!(cap.take().is_empty());
    }

    #[test]
    fn take_drains_incrementally() {
        let mut cap = capture_at(Level::Info);
        info("t", "one");
        assert_eq!(cap.take().len(), 1);
        info("t", "two");
        let got = cap.take();
        assert_eq!(got.len(), 1);
        assert!(got[0].contains("two"));
    }

    #[test]
    fn reset_rereads_environment() {
        // serialize with other capture tests — we poke global state
        let _cap = capture_at(Level::Info);
        set_level(Level::Error);
        reset_for_test();
        // next record re-derives from env (default warn unless set)
        let expected = Level::from_env();
        log(expected, "t", "after reset");
        // the lazy path cached it again
        assert_ne!(
            THRESHOLD.load(Ordering::Relaxed),
            u8::MAX,
            "threshold should be re-cached after first log"
        );
    }
}
