//! From-scratch substrates (DESIGN.md §4).
//!
//! The offline build environment resolves only the `xla` crate closure, so
//! the facilities other projects pull from crates.io are implemented here:
//! JSON (`json`), PRNG + distributions (`rng`), CLI parsing (`cli`),
//! statistics (`stats`), a thread pool (`threadpool`), a property-testing
//! harness (`prop`), a benchmark harness (`bench`), and table/chart
//! rendering (`table`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
