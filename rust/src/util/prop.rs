//! Mini property-testing harness (replaces `proptest`, unavailable offline).
//!
//! `check(name, cases, f)` runs `f` against `cases` deterministic
//! pseudo-random `Gen` instances (seeds 0..cases). On failure it re-runs
//! with smaller size hints to find a simpler failing seed, then panics with
//! the seed so the case can be replayed exactly:
//!
//! ```no_run
//! use dancemoe::util::prop;
//! prop::check("sum is commutative", 200, |g| {
//!     let a = g.f64_in(0.0, 1.0);
//!     let b = g.f64_in(0.0, 1.0);
//!     prop::assert_prop(a + b == b + a, "commutativity");
//! });
//! ```

use super::rng::Rng;

/// Random-input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Size hint: generators scale collection sizes by this (1.0 = full).
    pub size: f64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Gen {
        Gen {
            rng: Rng::new(seed.wrapping_mul(0x9e37_79b9).wrapping_add(seed)),
            size,
            seed,
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        if lo == hi {
            return lo;
        }
        // scale the upper end by the size hint, but never below lo+1 span
        let span = ((hi - lo) as f64 * self.size).ceil().max(1.0) as usize;
        lo + self.rng.below(span.min(hi - lo) + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Nonnegative weight vector with occasional zeros (common edge case in
    /// activation-frequency tables).
    pub fn weights(&mut self, len: usize) -> Vec<f64> {
        (0..len)
            .map(|_| {
                if self.rng.bool(0.15) {
                    0.0
                } else {
                    self.rng.range_f64(0.0, 1.0)
                }
            })
            .collect()
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn pick<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        self.rng.choose(v)
    }
}

/// Property assertion that formats context into the panic message.
pub fn assert_prop(cond: bool, msg: &str) {
    assert!(cond, "property violated: {msg}");
}

/// Run `f` against `cases` generated inputs. Panics on the first failure,
/// reporting the failing seed (replay by calling `f(&mut Gen::new(seed, sz))`).
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    f: F,
) {
    // escalate sizes: early cases are small (easier to debug), later larger.
    for case in 0..cases {
        let size = 0.2 + 0.8 * (case as f64 / cases.max(1) as f64);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(case, size);
            f(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| {
                    payload.downcast_ref::<&str>().map(|s| s.to_string())
                })
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at seed {case} (size {size:.2}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("tautology", 50, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert_prop((0.0..1.0).contains(&x), "in range");
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |_| {
            assert_prop(false, "nope");
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(3, 1.0);
        let mut b = Gen::new(3, 1.0);
        for _ in 0..10 {
            assert_eq!(a.usize_in(0, 100), b.usize_in(0, 100));
        }
    }

    #[test]
    fn usize_in_respects_bounds() {
        let mut g = Gen::new(1, 1.0);
        for _ in 0..500 {
            let x = g.usize_in(3, 9);
            assert!((3..=9).contains(&x));
        }
        assert_eq!(g.usize_in(5, 5), 5);
    }

    #[test]
    fn weights_has_zero_and_nonzero() {
        let mut g = Gen::new(2, 1.0);
        let w: Vec<f64> = (0..50).flat_map(|_| g.weights(10)).collect();
        assert!(w.iter().any(|&x| x == 0.0));
        assert!(w.iter().any(|&x| x > 0.0));
    }
}
