//! Deterministic PRNG + the distributions the workload generator needs.
//!
//! Replaces the `rand`/`rand_distr` crates (unavailable offline). The core
//! generator is PCG64 (O'Neill's PCG XSL RR 128/64), seeded through
//! SplitMix64 so small integer seeds decorrelate. All samplers are
//! deterministic given the seed, which makes every experiment in `exp/`
//! exactly reproducible.

/// PCG XSL RR 128/64 — fast, statistically solid, 2^128 period.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Rng {
    /// Seed via SplitMix64 expansion so seeds 0,1,2,… are decorrelated.
    pub fn new(seed: u64) -> Rng {
        let mut sm = SplitMix64 { s: seed };
        let state = ((sm.next() as u128) << 64) | sm.next() as u128;
        let inc = (((sm.next() as u128) << 64) | sm.next() as u128) | 1;
        let mut rng = Rng { state, inc };
        rng.next_u64(); // advance past the seed-correlated first output
        rng
    }

    /// Derive an independent child stream (for per-server / per-task RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller; one value per call, simple & adequate).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exp(rate): inter-arrival times of a Poisson process with the given
    /// rate (events per unit time).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Poisson(lambda) count (Knuth for small lambda, normal approx above).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = lambda + lambda.sqrt() * self.normal();
            x.max(0.0).round() as u64
        }
    }

    /// Gamma(shape, scale=1) via Marsaglia–Tsang (shape >= some small eps).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }

    /// Dirichlet(alpha) — the task-profile skew generator. Returns a
    /// probability vector of `alpha.len()` entries summing to 1.
    pub fn dirichlet(&mut self, alpha: &[f64]) -> Vec<f64> {
        assert!(!alpha.is_empty());
        let mut g: Vec<f64> = alpha.iter().map(|&a| self.gamma(a)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            // pathological underflow: fall back to uniform
            let u = 1.0 / g.len() as f64;
            g.iter_mut().for_each(|x| *x = u);
        } else {
            g.iter_mut().for_each(|x| *x /= sum);
        }
        g
    }

    /// Symmetric Dirichlet with concentration `alpha` over `n` categories.
    pub fn dirichlet_sym(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        self.dirichlet(&vec![alpha; n])
    }

    /// Sample an index from an (unnormalized) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with zero total weight");
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample `k` distinct indices from a weight vector (top-k routing with
    /// probability-proportional draws, without replacement).
    pub fn categorical_k(&mut self, weights: &[f64], k: usize) -> Vec<usize> {
        let k = k.min(weights.len());
        let mut w = weights.to_vec();
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            let i = self.categorical(&w);
            out.push(i);
            w[i] = 0.0;
            if w.iter().sum::<f64>() <= 0.0 {
                // degenerate: fill with unused indices deterministically
                for j in 0..w.len() {
                    if out.len() == k {
                        break;
                    }
                    if !out.contains(&j) {
                        out.push(j);
                    }
                }
                break;
            }
        }
        out
    }

    /// Zipf(s) over ranks 1..=n (heavy-tailed request popularity).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF over the normalized harmonic weights; n is small in
        // all our uses (task mixes), so O(n) is fine.
        let weights: Vec<f64> =
            (1..=n).map(|r| 1.0 / (r as f64).powf(s)).collect();
        self.categorical(&weights)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Choose one element by reference.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len())]
    }
}

/// SplitMix64: seed expander for PCG initialization.
struct SplitMix64 {
    s: u64,
}

impl SplitMix64 {
    fn next(&mut self) -> u64 {
        self.s = self.s.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.s;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let mut r3 = Rng::new(2);
        let s1: Vec<u64> = (0..16).map(|_| r1.next_u64()).collect();
        let s2: Vec<u64> = (0..16).map(|_| r2.next_u64()).collect();
        let s3: Vec<u64> = (0..16).map(|_| r3.next_u64()).collect();
        assert_eq!(s1, s2);
        assert_ne!(s1, s3);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let rate = 0.1; // mean 10 — the paper's BigBench arrival process
        let n = 50_000;
        let mean =
            (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.2, "mean {mean}");
    }

    #[test]
    fn poisson_mean_small_and_large_lambda() {
        let mut r = Rng::new(17);
        for lambda in [2.0, 60.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.poisson(lambda)).sum::<u64>() as f64
                / n as f64;
            assert!(
                (mean - lambda).abs() < lambda * 0.05 + 0.1,
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(19);
        for shape in [0.3, 1.0, 4.5] {
            let n = 30_000;
            let mean =
                (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.08 * shape.max(1.0),
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_skews() {
        let mut r = Rng::new(23);
        let p = r.dirichlet_sym(0.1, 8);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x >= 0.0));
        // low concentration => skewed: max component should dominate
        let avg_max: f64 = (0..200)
            .map(|_| {
                r.dirichlet_sym(0.1, 8)
                    .into_iter()
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / 200.0;
        assert!(avg_max > 0.5, "expected skew, got avg max {avg_max}");
        // high concentration => near-uniform
        let avg_max_hi: f64 = (0..200)
            .map(|_| {
                r.dirichlet_sym(50.0, 8)
                    .into_iter()
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / 200.0;
        assert!(avg_max_hi < 0.25, "expected uniform, got {avg_max_hi}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(29);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn categorical_k_distinct() {
        let mut r = Rng::new(31);
        for _ in 0..200 {
            let w = [0.5, 0.1, 0.2, 0.05, 0.15];
            let ks = r.categorical_k(&w, 3);
            assert_eq!(ks.len(), 3);
            let mut sorted = ks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 3, "duplicates in {ks:?}");
        }
    }

    #[test]
    fn categorical_k_degenerate_weights() {
        let mut r = Rng::new(37);
        // only one nonzero weight but k=3: must still return 3 distinct
        let ks = r.categorical_k(&[0.0, 1.0, 0.0, 0.0], 3);
        assert_eq!(ks.len(), 3);
        assert_eq!(ks[0], 1);
    }

    #[test]
    fn zipf_rank1_most_frequent() {
        let mut r = Rng::new(41);
        let mut counts = [0usize; 5];
        for _ in 0..10_000 {
            counts[r.zipf(5, 1.2)] += 1;
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[3]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(43);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let sa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let sb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(sa, sb);
    }
}
