//! Statistics helpers: entropy, percentiles, online accumulators, histograms.
//!
//! `entropy_bits` is the quantity at the heart of the paper's Algorithm 1
//! (layer-wise expert count allocation); the rest supports the metrics
//! pipeline and the experiment reports.

/// Shannon entropy of a (possibly unnormalized) nonnegative weight vector,
/// in **bits** (log base 2), matching the paper's `v_{n,l}` definition.
/// Zero-weight entries contribute nothing; an all-zero vector has entropy 0.
pub fn entropy_bits(weights: &[f64]) -> f64 {
    let total: f64 = weights.iter().filter(|w| **w > 0.0).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &w in weights {
        if w > 0.0 {
            let p = w / total;
            h -= p * p.log2();
        }
    }
    h
}

/// Normalize a weight vector into a probability vector (uniform if all-zero).
pub fn normalize(weights: &[f64]) -> Vec<f64> {
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return vec![1.0 / weights.len() as f64; weights.len()];
    }
    weights.iter().map(|w| w / total).collect()
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Percentile (nearest-rank on a sorted copy); `q` in [0,1].
///
/// The one shared implementation for the whole crate (engine metrics,
/// stats bus, gateway/regions reports, latency decomposition). NaN inputs
/// are ignored, so the result is never NaN; an empty (or all-NaN) slice
/// yields 0.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    percentiles(xs, &[q])[0]
}

/// Several percentiles of the same sample in one sort; `qs` in [0,1].
///
/// Same nearest-rank and NaN-ignoring semantics as [`percentile`] —
/// `percentiles(xs, &[q])[0] == percentile(xs, q)` — but pays the
/// sort once for a whole p50/p95/p99 triple.
pub fn percentiles(xs: &[f64], qs: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> =
        xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return vec![0.0; qs.len()];
    }
    v.sort_by(f64::total_cmp);
    qs.iter()
        .map(|q| {
            let idx = ((v.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).round()
                as usize;
            v[idx]
        })
        .collect()
}

/// Indices that would sort `xs` descending (stable for equal keys).
pub fn argsort_desc(xs: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap().then(a.cmp(&b))
    });
    idx
}

/// Top-k indices by value, descending.
pub fn top_k_desc(xs: &[f64], k: usize) -> Vec<usize> {
    let mut idx = argsort_desc(xs);
    idx.truncate(k);
    idx
}

/// Online mean/variance/min/max accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Online {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
    pub sum: f64,
}

impl Online {
    pub fn new() -> Online {
        Online {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn merge(&mut self, other: &Online) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean = (self.mean * self.n as f64
            + other.mean * other.n as f64)
            / n;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bucket latency histogram (log-spaced), for serve reports.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    /// bucket upper bounds in seconds
    bounds: Vec<f64>,
    counts: Vec<u64>,
    pub online: Online,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> LatencyHist {
        // 0.01s .. ~500s, ×1.6 per bucket
        let mut bounds = Vec::new();
        let mut b = 0.01;
        while b < 500.0 {
            bounds.push(b);
            b *= 1.6;
        }
        bounds.push(f64::INFINITY);
        let n = bounds.len();
        LatencyHist {
            bounds,
            counts: vec![0; n],
            online: Online::new(),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.online.push(x);
        let i = self
            .bounds
            .iter()
            .position(|&b| x <= b)
            .unwrap_or(self.bounds.len() - 1);
        self.counts[i] += 1;
    }

    pub fn quantile(&self, q: f64) -> f64 {
        let total: u64 = self.counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.bounds[i].min(self.online.max);
            }
        }
        self.online.max
    }
}

/// Linear least-squares fit `y = a + b x` — the paper's simulator uses a
/// "linear model to predict processing time per token batch"; calibration
/// fits it to measured PJRT wall-clock.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    if den == 0.0 {
        return (my, 0.0);
    }
    let b = num / den;
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_extremes() {
        assert_eq!(entropy_bits(&[1.0, 0.0, 0.0]), 0.0);
        let h = entropy_bits(&[1.0; 8]);
        assert!((h - 3.0).abs() < 1e-12); // log2(8)
        assert_eq!(entropy_bits(&[0.0, 0.0]), 0.0);
        assert_eq!(entropy_bits(&[]), 0.0);
    }

    #[test]
    fn entropy_scale_invariant() {
        let a = entropy_bits(&[0.2, 0.3, 0.5]);
        let b = entropy_bits(&[2.0, 3.0, 5.0]);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn entropy_monotone_in_uniformity() {
        let skewed = entropy_bits(&[0.9, 0.05, 0.03, 0.02]);
        let flat = entropy_bits(&[0.25; 4]);
        assert!(skewed < flat);
    }

    #[test]
    fn normalize_handles_zero() {
        assert_eq!(normalize(&[0.0, 0.0]), vec![0.5, 0.5]);
        let p = normalize(&[1.0, 3.0]);
        assert!((p[1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn percentile_edge_cases() {
        // single sample: every quantile is that sample
        assert_eq!(percentile(&[7.5], 0.0), 7.5);
        assert_eq!(percentile(&[7.5], 0.5), 7.5);
        assert_eq!(percentile(&[7.5], 1.0), 7.5);
        // out-of-range q clamps rather than panics
        assert_eq!(percentile(&[1.0, 2.0], -0.5), 1.0);
        assert_eq!(percentile(&[1.0, 2.0], 2.0), 2.0);
    }

    #[test]
    fn percentile_never_nan() {
        // NaN inputs are ignored, not propagated (and never panic)
        let xs = [3.0, f64::NAN, 1.0, f64::NAN, 2.0];
        let p = percentile(&xs, 0.5);
        assert!(!p.is_nan());
        assert_eq!(p, 2.0);
        // all-NaN behaves like empty
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 0.9), 0.0);
        assert!(!percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn percentiles_match_percentile() {
        let xs = [0.9, 0.1, 0.5, 0.7, 0.3, 0.2, 0.8];
        let qs = [0.0, 0.25, 0.5, 0.95, 0.99, 1.0];
        let multi = percentiles(&xs, &qs);
        for (i, &q) in qs.iter().enumerate() {
            assert_eq!(multi[i], percentile(&xs, q));
        }
        assert_eq!(percentiles(&[], &[0.5, 0.9]), vec![0.0, 0.0]);
        assert!(percentiles(&xs, &[]).is_empty());
    }

    #[test]
    fn argsort_and_topk() {
        let xs = [0.1, 0.9, 0.4, 0.9];
        assert_eq!(argsort_desc(&xs), vec![1, 3, 2, 0]); // stable tie
        assert_eq!(top_k_desc(&xs, 2), vec![1, 3]);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - 4.0).abs() < 1e-12);
        assert_eq!(o.min, 1.0);
        assert_eq!(o.max, 10.0);
        let var = xs
            .iter()
            .map(|x| (x - 4.0) * (x - 4.0))
            .sum::<f64>()
            / 4.0;
        assert!((o.var() - var).abs() < 1e-12);
    }

    #[test]
    fn online_merge() {
        let mut a = Online::new();
        let mut b = Online::new();
        let mut whole = Online::new();
        for i in 0..10 {
            let x = (i * i) as f64;
            whole.push(x);
            if i < 4 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.var() - whole.var()).abs() < 1e-9);
        assert_eq!(a.n, whole.n);
    }

    #[test]
    fn hist_quantiles_ordered() {
        let mut h = LatencyHist::new();
        for i in 1..=100 {
            h.push(i as f64 * 0.05);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p99 <= h.online.max + 1e-9);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
    }

    #[test]
    fn linear_fit_degenerate() {
        let (a, b) = linear_fit(&[2.0, 2.0], &[5.0, 7.0]);
        assert_eq!(b, 0.0);
        assert_eq!(a, 6.0);
    }
}
