//! Aligned-table rendering for the experiment reports (`exp/`).
//!
//! Produces the same row/column layout the paper's tables use, e.g.
//! `Method | Server 1 | Server 2 | Server 3 | Total Avg`, as plain aligned
//! text and as GitHub-flavored markdown (used in EXPERIMENTS.md).

/// A simple table: header + rows of strings.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// Convenience: label + numeric cells with fixed precision.
    pub fn row_f64(&mut self, label: &str, values: &[f64], precision: usize) {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|v| format!("{v:.precision$}")));
        self.row(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Plain aligned-text rendering.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                // right-align numeric-looking cells, left-align labels
                if i == 0 {
                    s.push_str(&format!("{:<width$}", c, width = w[i]));
                } else {
                    s.push_str(&format!("{:>width$}", c, width = w[i]));
                }
            }
            s
        };
        out.push_str(&line(&self.header, &w));
        out.push('\n');
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavored markdown rendering (for EXPERIMENTS.md).
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.header.len())
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Render an ASCII bar chart (figures 2/3/5/6/7/8 are plots in the paper;
/// we print their series as labelled bars / columns).
pub fn bar_chart(title: &str, labels: &[String], values: &[f64]) -> String {
    assert_eq!(labels.len(), values.len());
    let maxv = values.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
    let lw = labels.iter().map(|l| l.chars().count()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (l, &v) in labels.iter().zip(values) {
        let n = ((v / maxv) * 48.0).round().max(0.0) as usize;
        out.push_str(&format!(
            "  {:<lw$} |{} {:.4}\n",
            l,
            "█".repeat(n),
            v,
            lw = lw
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["Method", "S1", "Total Avg"]);
        t.row_f64("Uniform", &[48.55, 21.66], 2);
        t.row_f64("Ours", &[14.67, 6.63], 2);
        let s = t.render();
        assert!(s.contains("Method"));
        assert!(s.contains("48.55"));
        // each data line has the same display width
        let lines: Vec<&str> = s.lines().skip(1).collect();
        let w0 = lines[1].chars().count();
        assert_eq!(lines[2].chars().count(), w0);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.starts_with("| a | b |\n|---|---|\n| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart(
            "demo",
            &["e0".into(), "e1".into()],
            &[1.0, 0.5],
        );
        assert!(s.contains("e0"));
        let bars: Vec<usize> = s
            .lines()
            .skip(1)
            .map(|l| l.matches('█').count())
            .collect();
        assert!(bars[0] > bars[1]);
    }
}
