//! A small fixed-size worker pool + `parallel_map` (replaces tokio for the
//! CPU-bound fan-out in the benchmark sweeps; the request path itself is a
//! single-threaded discrete-event loop, which is both faster and exactly
//! reproducible).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("dancemoe-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
        }
    }

    /// Number of hardware threads, clamped for the sweep workloads.
    pub fn default_threads() -> usize {
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("worker alive");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Apply `f` to every item on a transient pool and return results in input
/// order. Used by the experiment sweeps (each item is an independent
/// simulation run with its own RNG, so parallelism preserves determinism).
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.into_iter().map(f).collect();
    }
    let pool = ThreadPool::new(threads);
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    for (i, item) in items.into_iter().enumerate() {
        let tx = tx.clone();
        let f = Arc::clone(&f);
        pool.execute(move || {
            let r = f(item);
            let _ = tx.send((i, r));
        });
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    out.into_iter().map(|r| r.expect("worker completed")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..64).collect();
        let out = parallel_map(items, 8, |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread_and_empty() {
        assert_eq!(parallel_map(vec![1, 2, 3], 1, |x| x + 1), vec![2, 3, 4]);
        let empty: Vec<usize> = vec![];
        assert!(parallel_map(empty, 4, |x: usize| x).is_empty());
    }
}
