//! A small fixed-size worker pool + `parallel_map` (replaces tokio for the
//! CPU-bound fan-out in the benchmark sweeps; the request path itself is a
//! single-threaded discrete-event loop, which is both faster and exactly
//! reproducible), plus `WorkerCrew`: long-lived workers that each own a
//! contiguous chunk of stateful items and answer addressed commands over
//! bounded channels. The crew is the substrate for the sharded region
//! engine — worker panics propagate to the caller instead of hanging the
//! orchestrator, and dropping the crew shuts the workers down.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("dancemoe-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
        }
    }

    /// Number of hardware threads, clamped for the sweep workloads.
    pub fn default_threads() -> usize {
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("worker alive");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Render a panic payload (the `Box<dyn Any>` from `catch_unwind`) as the
/// original message when it was a string, so the re-raised panic on the
/// calling thread keeps the worker's diagnostic.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Apply `f` to every item on a transient pool and return results in input
/// order. Used by the experiment sweeps (each item is an independent
/// simulation run with its own RNG, so parallelism preserves determinism).
/// A panic inside `f` is resumed on the calling thread instead of leaving
/// a hole in the results (the old behaviour was a confusing
/// "worker completed" panic with the original message lost).
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.into_iter().map(f).collect();
    }
    let pool = ThreadPool::new(threads);
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel::<(usize, thread::Result<R>)>();
    for (i, item) in items.into_iter().enumerate() {
        let tx = tx.clone();
        let f = Arc::clone(&f);
        pool.execute(move || {
            let r = catch_unwind(AssertUnwindSafe(|| f(item)));
            let _ = tx.send((i, r));
        });
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        match r {
            Ok(r) => out[i] = Some(r),
            Err(payload) => resume_unwind(payload),
        }
    }
    out.into_iter().map(|r| r.expect("worker completed")).collect()
}

type CrewCmdLane<C> = mpsc::SyncSender<(usize, C)>;
type CrewReplyLane<Rp> = mpsc::Receiver<(usize, Rp)>;

/// Long-lived worker threads that each own a contiguous chunk of items
/// (`S`) and apply a shared handler to addressed commands. Commands and
/// replies travel over bounded (`sync_channel`) lanes sized to the chunk,
/// which is exactly enough for the crew's send-all-then-collect-all usage
/// pattern; a worker that panics stores the panic message and drops its
/// reply lane, so the next collect raises on the calling thread instead
/// of blocking forever. `finish` returns the items (in their original
/// order) for reassembly; dropping the crew without `finish` still joins
/// every worker.
pub struct WorkerCrew<S, C, Rp> {
    cmd_txs: Vec<CrewCmdLane<C>>,
    reply_rxs: Vec<CrewReplyLane<Rp>>,
    handles: Vec<thread::JoinHandle<Vec<S>>>,
    /// `ranges[w]` is the global item range owned by worker `w`.
    ranges: Vec<std::ops::Range<usize>>,
    /// Global item index -> owning worker.
    owner: Vec<usize>,
    panic_slot: Arc<Mutex<Option<String>>>,
}

impl<S, C, Rp> WorkerCrew<S, C, Rp>
where
    S: Send + 'static,
    C: Send + 'static,
    Rp: Send + 'static,
{
    /// Spawn `workers` threads (clamped to `[1, items.len()]`), splitting
    /// `items` into contiguous chunks by ceiling division. The handler runs
    /// on the owning worker with exclusive access to the item.
    pub fn new<H>(items: Vec<S>, workers: usize, handler: H) -> WorkerCrew<S, C, Rp>
    where
        H: Fn(&mut S, C) -> Rp + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return WorkerCrew {
                cmd_txs: Vec::new(),
                reply_rxs: Vec::new(),
                handles: Vec::new(),
                ranges: Vec::new(),
                owner: Vec::new(),
                panic_slot: Arc::new(Mutex::new(None)),
            };
        }
        let workers = workers.clamp(1, n);
        let chunk = n.div_ceil(workers);
        let handler = Arc::new(handler);
        let panic_slot = Arc::new(Mutex::new(None));
        let mut cmd_txs = Vec::with_capacity(workers);
        let mut reply_rxs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        let mut ranges = Vec::with_capacity(workers);
        let mut owner = vec![0usize; n];
        let mut items = items.into_iter();
        let mut base = 0usize;
        for w in 0..workers {
            let take = chunk.min(n - base);
            let mine: Vec<S> = items.by_ref().take(take).collect();
            for o in owner.iter_mut().skip(base).take(take) {
                *o = w;
            }
            ranges.push(base..base + take);
            let (cmd_tx, cmd_rx) = mpsc::sync_channel::<(usize, C)>(take.max(1));
            let (reply_tx, reply_rx) = mpsc::sync_channel::<(usize, Rp)>(take.max(1));
            let handler = Arc::clone(&handler);
            let slot = Arc::clone(&panic_slot);
            let handle = thread::Builder::new()
                .name(format!("dancemoe-crew-{w}"))
                .spawn(move || {
                    let mut mine = mine;
                    while let Ok((local, cmd)) = cmd_rx.recv() {
                        let run = AssertUnwindSafe(|| handler(&mut mine[local], cmd));
                        match catch_unwind(run) {
                            Ok(reply) => {
                                if reply_tx.send((base + local, reply)).is_err() {
                                    break;
                                }
                            }
                            Err(payload) => {
                                let msg = panic_message(payload.as_ref());
                                *slot.lock().unwrap() = Some(msg);
                                // Dropping the reply lane wakes the caller,
                                // which re-raises the stored message.
                                break;
                            }
                        }
                    }
                    mine
                })
                .expect("spawn crew worker");
            cmd_txs.push(cmd_tx);
            reply_rxs.push(reply_rx);
            handles.push(handle);
            base += take;
        }
        WorkerCrew {
            cmd_txs,
            reply_rxs,
            handles,
            ranges,
            owner,
            panic_slot,
        }
    }

    /// Number of items the crew owns.
    pub fn len(&self) -> usize {
        self.owner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.owner.is_empty()
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    fn raise_if_panicked(&self) -> ! {
        let msg = self
            .panic_slot
            .lock()
            .unwrap()
            .take()
            .unwrap_or_else(|| "worker disconnected".to_string());
        panic!("crew worker panicked: {msg}");
    }

    fn send(&self, i: usize, cmd: C) {
        let w = self.owner[i];
        let local = i - self.ranges[w].start;
        if self.cmd_txs[w].send((local, cmd)).is_err() {
            self.raise_if_panicked();
        }
    }

    fn recv_from(&self, w: usize, expect_item: usize) -> Rp {
        match self.reply_rxs[w].recv() {
            Ok((i, reply)) => {
                assert_eq!(i, expect_item, "crew reply out of order");
                reply
            }
            Err(_) => self.raise_if_panicked(),
        }
    }

    /// Send `mk(i)` to every item in index order, then collect one reply
    /// per item, returned in index order. Workers process their chunks
    /// concurrently; the bounded lanes hold a full round without blocking
    /// the sender.
    pub fn broadcast<M: FnMut(usize) -> C>(&self, mut mk: M) -> Vec<Rp> {
        let n = self.len();
        for i in 0..n {
            self.send(i, mk(i));
        }
        (0..n).map(|i| self.recv_from(self.owner[i], i)).collect()
    }

    /// Send one command to one item and wait for its reply.
    pub fn send_one(&self, i: usize, cmd: C) -> Rp {
        self.send(i, cmd);
        self.recv_from(self.owner[i], i)
    }

    /// Shut the workers down and return the items in their original order.
    pub fn finish(mut self) -> Vec<S> {
        self.cmd_txs.clear();
        self.reply_rxs.clear();
        let handles = std::mem::take(&mut self.handles);
        let mut out = Vec::with_capacity(self.owner.len());
        for h in handles {
            match h.join() {
                Ok(chunk) => out.extend(chunk),
                Err(payload) => {
                    panic!("crew worker panicked: {}", panic_message(payload.as_ref()))
                }
            }
        }
        out
    }
}

impl<S, C, Rp> Drop for WorkerCrew<S, C, Rp> {
    fn drop(&mut self) {
        // Closing the command lanes ends each worker's recv loop; join so
        // no detached thread outlives the crew.
        self.cmd_txs.clear();
        self.reply_rxs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..64).collect();
        let out = parallel_map(items, 8, |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread_and_empty() {
        assert_eq!(parallel_map(vec![1, 2, 3], 1, |x| x + 1), vec![2, 3, 4]);
        let empty: Vec<usize> = vec![];
        assert!(parallel_map(empty, 4, |x: usize| x).is_empty());
    }

    #[test]
    fn parallel_map_propagates_worker_panics() {
        let res = catch_unwind(AssertUnwindSafe(|| {
            parallel_map((0..16).collect::<Vec<usize>>(), 4, |x| {
                if x == 7 {
                    panic!("item seven exploded");
                }
                x
            })
        }));
        let payload = res.expect_err("panic must propagate");
        assert_eq!(panic_message(payload.as_ref()), "item seven exploded");
    }

    #[test]
    fn crew_broadcast_and_finish_preserve_order() {
        let crew: WorkerCrew<usize, usize, usize> =
            WorkerCrew::new((0..10).collect(), 3, |item, add| {
                *item += add;
                *item
            });
        let replies = crew.broadcast(|i| i * 100);
        assert_eq!(replies, (0..10).map(|i| i + i * 100).collect::<Vec<usize>>());
        assert_eq!(crew.send_one(4, 1), 4 + 400 + 1);
        let items = crew.finish();
        let mut want: Vec<usize> = (0..10).map(|i| i + i * 100).collect();
        want[4] += 1;
        assert_eq!(items, want);
    }

    #[test]
    fn crew_propagates_worker_panic_instead_of_hanging() {
        let crew: WorkerCrew<usize, usize, usize> =
            WorkerCrew::new((0..8).collect(), 4, |item, cmd| {
                if *item == 5 {
                    panic!("shard five died");
                }
                *item + cmd
            });
        let res = catch_unwind(AssertUnwindSafe(|| crew.broadcast(|_| 1)));
        let payload = res.expect_err("crew panic must propagate");
        let msg = panic_message(payload.as_ref());
        assert!(msg.contains("shard five died"), "got: {msg}");
    }

    #[test]
    fn crew_zero_workers_clamps_to_one() {
        let crew: WorkerCrew<usize, usize, usize> =
            WorkerCrew::new(vec![10, 20], 0, |item, cmd| *item + cmd);
        assert_eq!(crew.workers(), 1);
        assert_eq!(crew.broadcast(|_| 5), vec![15, 25]);
        assert_eq!(crew.finish(), vec![10, 20]);
    }

    #[test]
    fn crew_oversubscribed_clamps_to_item_count() {
        let crew: WorkerCrew<usize, usize, usize> =
            WorkerCrew::new(vec![1, 2, 3], 16, |item, cmd| *item * cmd);
        assert_eq!(crew.workers(), 3);
        assert_eq!(crew.broadcast(|_| 2), vec![2, 4, 6]);
        assert_eq!(crew.finish(), vec![1, 2, 3]);
    }

    #[test]
    fn crew_empty_items() {
        let crew: WorkerCrew<usize, usize, usize> =
            WorkerCrew::new(Vec::new(), 4, |item, _cmd: usize| *item);
        assert!(crew.is_empty());
        assert!(crew.broadcast(|_| 0).is_empty());
        assert!(crew.finish().is_empty());
    }

    #[test]
    fn crew_shutdown_on_drop_joins_workers() {
        let touched = Arc::new(AtomicUsize::new(0));
        {
            let t = Arc::clone(&touched);
            let crew: WorkerCrew<usize, usize, usize> =
                WorkerCrew::new((0..6).collect(), 2, move |item, cmd| {
                    t.fetch_add(1, Ordering::SeqCst);
                    *item + cmd
                });
            let _ = crew.broadcast(|_| 0);
            // Dropped without finish(): must join, not hang or leak.
        }
        assert_eq!(touched.load(Ordering::SeqCst), 6);
    }
}
