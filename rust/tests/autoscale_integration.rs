//! End-to-end tests for the expert replica autoscaler: burst-driven
//! scale-out and trough-driven drained scale-in on the edge preset, the
//! p95 comparison against a fixed-placement gateway, the
//! migration↔autoscale memory arbitration, and the drained-replica
//! routing safety properties.

use dancemoe::autoscale::AutoscaleConfig;
use dancemoe::config::{ClusterConfig, ModelConfig, WorkloadConfig};
use dancemoe::coordinator::CoordinatorConfig;
use dancemoe::engine::{CostModel, Engine, EngineConfig, ScaleKind};
use dancemoe::placement::{uniform, MemoryLedger};
use dancemoe::serve::{ArrivalProfile, Gateway, GatewayConfig};
use dancemoe::util::prop;

// ---- the one timing vocabulary every test below speaks -----------------
// The control interval, burst shape and drain window interlock: the
// hysteresis band is tuned for CONTROL_INTERVAL_S-spaced observations of
// BURST_S-long bursts, and drains must finish well inside a burst period
// so scale-ins land before the next burst. Keeping them named (instead of
// the magic 15.0/30.0/120.0/5.0 literals the assertions used to repeat)
// makes that coupling explicit and retunable in one place.

/// Coordinator control interval the EWMA band below is tuned for.
const CONTROL_INTERVAL_S: f64 = 15.0;
/// Burst length of the bursty arrival profile.
const BURST_S: f64 = 30.0;
/// Burst period of the bursty arrival profile.
const BURST_PERIOD_S: f64 = 120.0;
/// Rate multiplier during bursts.
const BURST_FACTOR: f64 = 4.0;
/// Drain window before a scaled-in replica is evicted (≪ BURST_PERIOD_S).
const DRAIN_S: f64 = 5.0;

/// Trimmed Mixtral topology with proportionally tight GPU memory: enough
/// for full coverage plus ~30 % replication slack, so replica decisions
/// stay meaningful (paper-preset memory would let every server hold every
/// trimmed-model expert and leave the autoscaler nothing to do).
fn small_tight() -> (ModelConfig, ClusterConfig, WorkloadConfig) {
    let mut m = ModelConfig::mixtral_8x7b_sim();
    m.num_layers = 4;
    let mut c = ClusterConfig::edge_testbed_3_for(&m);
    let slots = (m.total_experts() as f64 * 1.3 / 4.0).ceil() as u64;
    for s in &mut c.servers {
        for g in &mut s.gpus {
            g.mem_bytes = m.expert_bytes * slots;
        }
    }
    (m, c, WorkloadConfig::bigbench(1.0)) // 3 req/s aggregate
}

fn bursty() -> ArrivalProfile {
    ArrivalProfile::Bursty {
        factor: BURST_FACTOR,
        burst_s: BURST_S,
        period_s: BURST_PERIOD_S,
    }
}

fn autoscale_cfg() -> AutoscaleConfig {
    AutoscaleConfig {
        // band tuned for CONTROL_INTERVAL_S observations of BURST_S bursts
        hi_ratio: 1.2,
        lo_ratio: 0.85,
        min_load_tps: 20.0,
        drain_s: DRAIN_S,
        cooldown_intervals: 1,
        ..AutoscaleConfig::default()
    }
}

#[test]
fn bursts_scale_out_troughs_scale_in_and_p95_beats_fixed() {
    let (m, c, w) = small_tight();
    let gcfg = GatewayConfig {
        horizon_s: 600.0,
        profile: bursty(),
        seed: 41,
        ..GatewayConfig::default()
    };
    let initial = uniform::place(&m, &c);

    // ---- autoscaled run --------------------------------------------------
    let mut gw = Gateway::new(
        &m,
        &c,
        &w,
        initial.clone(),
        gcfg.clone(),
        CoordinatorConfig {
            interval_s: CONTROL_INTERVAL_S,
            seed: 41,
            autoscale: Some(autoscale_cfg()),
            ..CoordinatorConfig::default()
        },
    );
    let auto = gw.run();
    assert_eq!(auto.offered, auto.admitted + auto.shed);
    assert_eq!(auto.serve.records.len() as u64, auto.admitted);

    // replica counts rose during some burst...
    let outs: Vec<f64> = gw
        .engine
        .scale_events
        .iter()
        .filter(|e| e.applied && e.kind == ScaleKind::Out)
        .map(|e| e.t_s)
        .collect();
    assert!(
        !outs.is_empty(),
        "bursty load must trigger at least one scale-out"
    );
    let max_extra = gw
        .coordinator
        .autoscale_logs
        .iter()
        .map(|l| l.extra_replicas)
        .max()
        .unwrap();
    assert!(max_extra >= 1, "extra replicas must appear in the timeline");

    // ...and came back down after a trough (drained scale-in applied)
    let ins = gw
        .engine
        .scale_events
        .iter()
        .filter(|e| e.applied && e.kind == ScaleKind::In)
        .count();
    assert!(
        ins >= 1,
        "troughs must drain at least one added replica back out"
    );
    assert_eq!(auto.scale_outs as usize, outs.len());
    assert_eq!(auto.scale_ins as usize, ins);

    // placement stayed structurally sound throughout (memory + coverage)
    gw.engine.placement.validate().unwrap();
    // no drained-replica routing violation is possible structurally: every
    // draining replica is outside the owner set the engine routes over
    for (s, g, l, e) in gw.engine.placement.draining_replicas() {
        assert!(!gw.engine.placement.owners_ref(l, e).contains(&(s, g)));
        assert!(gw.engine.placement.active_count(l, e) >= 1);
    }

    // ---- fixed-placement run at the same arrival rate --------------------
    let mut fixed = Gateway::new(
        &m,
        &c,
        &w,
        initial,
        gcfg,
        CoordinatorConfig {
            interval_s: CONTROL_INTERVAL_S,
            migrate: false,
            seed: 41,
            ..CoordinatorConfig::default()
        },
    );
    let base = fixed.run();
    let (a95, f95) = (
        auto.latency_percentile(0.95),
        base.latency_percentile(0.95),
    );
    assert!(
        a95 < f95,
        "autoscaled p95 ({a95:.3}s) must beat the fixed-placement \
         gateway ({f95:.3}s) at the same arrival rate"
    );
}

#[test]
fn concurrent_migration_and_scale_out_respect_memory() {
    // The satellite invariant, end to end: drive both planners against a
    // near-full cluster and assert no (server, gpu) ever exceeds capacity
    // — the shared ledger plus apply-time caps make over-commit impossible.
    let (m, c, w) = small_tight();
    let mut gw = Gateway::new(
        &m,
        &c,
        &w,
        uniform::place(&m, &c),
        GatewayConfig {
            horizon_s: 300.0,
            profile: bursty(),
            seed: 43,
            ..GatewayConfig::default()
        },
        CoordinatorConfig {
            interval_s: CONTROL_INTERVAL_S,
            seed: 43,
            autoscale: Some(AutoscaleConfig {
                // aggressive: fire as often as possible to stress the ledger
                hi_ratio: 1.05,
                lo_ratio: 0.5,
                min_load_tps: 1.0,
                cooldown_intervals: 0,
                drain_s: 2.0,
                ..AutoscaleConfig::default()
            }),
            ..CoordinatorConfig::default()
        },
    );
    let report = gw.run();
    assert!(report.offered > 0);
    gw.engine.placement.validate().unwrap();
    // fold any completions the last interval didn't see, as the next tick
    // would (reservations for applied copies are released there)
    let completions = gw.engine.take_scale_completions();
    if let Some(a) = gw.coordinator.autoscaler.as_mut() {
        a.on_completions(&completions, &mut gw.coordinator.ledger);
    }
    let p = &gw.engine.placement;
    for s in 0..3 {
        for g in 0..p.gpus[s] {
            let used = p.mem_used(s, g) + gw.coordinator.ledger.reserved(s, g);
            assert!(
                used <= gw.coordinator.ledger.capacity(s, g),
                "s{s}g{g}: committed {used} exceeds capacity"
            );
        }
    }
}

#[test]
fn prop_drained_replicas_never_routable() {
    // Property (satellite): whatever sequence of placements and drains the
    // controller produces, a draining replica is invisible to every
    // routing surface — the owner set (engine's per-invocation choice) and
    // `server_has` (locality scores) — while still holding memory.
    let (m, c, _) = small_tight();
    prop::check("draining replicas take no traffic", 60, |g| {
        // full coverage first (uniform), then random extra replicas where
        // the tight memory allows them
        let mut p = uniform::place(&m, &c);
        for _ in 0..g.usize_in(0, 40) {
            let l = g.usize_in(0, m.num_layers - 1);
            let e = g.usize_in(0, m.num_experts - 1);
            let s = g.usize_in(0, 2);
            if p.server_holds(s, l, e) {
                continue;
            }
            let gpu = g.usize_in(0, p.gpus[s] - 1);
            let _ = p.place(s, gpu, l, e);
        }
        // drain a random subset (never the last active replica)
        let mut drained = Vec::new();
        for l in 0..m.num_layers {
            for e in 0..m.num_experts {
                if !g.bool() {
                    continue;
                }
                let owners = p.owners_ref(l, e).to_vec();
                if owners.len() < 2 {
                    continue;
                }
                let &(s, gpu) = g.pick(&owners);
                let mem_before = p.mem_used(s, gpu);
                p.begin_drain(s, gpu, l, e).unwrap();
                prop::assert_prop(
                    p.mem_used(s, gpu) == mem_before,
                    "drain must not free memory early",
                );
                drained.push((s, gpu, l, e));
            }
        }
        for &(s, gpu, l, e) in &drained {
            prop::assert_prop(
                !p.owners_ref(l, e).contains(&(s, gpu)),
                "draining replica still in the owner set",
            );
            prop::assert_prop(
                p.active_count(l, e) >= 1,
                "drain must never remove the last active replica",
            );
            let other_active = (0..p.gpus[s]).any(|og| {
                p.gpu_has(s, og, l, e) && !p.is_draining(s, og, l, e)
            });
            prop::assert_prop(
                p.server_has(s, l, e) == other_active,
                "server_has must reflect only active replicas",
            );
        }
        // eviction frees exactly the drained bytes
        for &(s, gpu, l, e) in &drained {
            let before = p.mem_used(s, gpu);
            p.finish_drain(s, gpu, l, e).unwrap();
            prop::assert_prop(
                p.mem_used(s, gpu) == before - m.expert_bytes,
                "eviction must free the replica's bytes",
            );
        }
        p.validate().unwrap();
    });
}

#[test]
fn scale_in_during_drain_is_rejected() {
    // The previously-missing rejection case: once a replica is draining,
    // a second ScaleIn for the same replica must be refused (not
    // double-counted in the in-flight ledger), and the sole remaining
    // active replica must be undrainable for the whole drain window.
    let (m, c, _) = small_tight();
    let mut engine = Engine::new(
        &m,
        &c,
        uniform::place(&m, &c),
        EngineConfig::default(),
        CostModel::default(),
    );
    let (l, e) = (0, 0);
    let src = engine.placement.owners_ref(l, e)[0].0;
    let dst = (0..3)
        .find(|&s| !engine.placement.server_holds(s, l, e))
        .unwrap();
    let at = engine.schedule_scale_out(l, e, dst, 0, src).unwrap();
    engine.run_until(at + 1.0);
    assert!(engine.placement.gpu_has(dst, 0, l, e), "copy landed");
    assert_eq!(engine.scale_ops_in_flight(), 0);

    let drain_done = engine.schedule_scale_in(l, e, dst, 0, DRAIN_S).unwrap();
    assert!(drain_done >= at, "drain completes in the future");
    assert_eq!(engine.scale_ops_in_flight(), 1);

    // same replica again: rejected, and the in-flight count is unchanged
    assert!(engine.schedule_scale_in(l, e, dst, 0, DRAIN_S).is_err());
    assert_eq!(engine.scale_ops_in_flight(), 1);

    // the drain removed (dst, 0) from the owner set, so every remaining
    // owner is the last active replica — undrainable
    let owners = engine.placement.owners_ref(l, e).to_vec();
    assert!(!owners.contains(&(dst, 0)));
    for &(s, g) in &owners {
        assert!(
            engine.schedule_scale_in(l, e, s, g, DRAIN_S).is_err(),
            "last active replica must be undrainable"
        );
    }
    assert_eq!(engine.scale_ops_in_flight(), 1, "rejections count nothing");

    // the drain window elapses: the replica is evicted, accounting closes
    engine.run_until(drain_done + 1.0);
    assert_eq!(engine.scale_ops_in_flight(), 0);
    assert!(!engine.placement.gpu_has(dst, 0, l, e), "evicted");
    engine.placement.validate().unwrap();
}

#[test]
fn ledger_is_shared_between_migration_and_autoscale_paths() {
    // Unit-level arbitration: while the autoscaler has bytes reserved, the
    // remaining free space the migration planner can see shrinks by
    // exactly that amount.
    let (m, c, _) = small_tight();
    let p = uniform::place(&m, &c);
    let mut ledger = MemoryLedger::new(&c);
    let free0 = ledger.free(&p, 0, 0);
    assert!(free0 >= m.expert_bytes, "tight preset still has slack");
    assert!(ledger.try_reserve(&p, 0, 0, m.expert_bytes));
    assert_eq!(ledger.free(&p, 0, 0), free0 - m.expert_bytes);
    ledger.release(0, 0, m.expert_bytes);
    assert_eq!(ledger.free(&p, 0, 0), free0);
}
