//! Property/invariant suite for the chaos subsystem.
//!
//! Locks the recovery contracts faults must never break:
//!
//! - **Conservation**: per region and globally, every arrival is exactly
//!   one of {admitted, shed, spilled-and-admitted-elsewhere,
//!   spilled-and-shed} across randomized fault schedules of every class
//!   (crash-only, partition-only, mixed, crash-racing-scale-out).
//! - **Ledger balance**: the run ends with zero outstanding memory
//!   reservations — a crash refunds every in-flight copy exactly once.
//! - **The copy-races-crash regression**: a scale-out copy in flight to
//!   a server that dies must refund its reservation exactly once and
//!   never produce a routable phantom replica.
//! - **Fault-triggered flight dumps**: at most one dump per fault
//!   event, ring contents end at the fault timestamp, and the dump-cap
//!   drop counter surfaces overflow.
//! - **Deterministic replay**: same seed + schedule ⇒ byte-identical
//!   `BENCH_chaos.json` serialization (two seeds, matching the pattern
//!   of the other serving suites), and an empty schedule is
//!   byte-identical to the plain (fault-free) regions run.
//!
//! Everything is deterministic and single-threaded per test, so the
//! suite passes under any `--test-threads` setting.

use dancemoe::chaos::{
    bench_file_json, ChaosClass, ChaosReport, ChaosScenario, FaultSchedule,
};
use dancemoe::config::{ClusterConfig, ModelConfig};
use dancemoe::coordinator::{Coordinator, CoordinatorConfig};
use dancemoe::engine::{CostModel, Engine, EngineConfig, ScaleKind};
use dancemoe::obs::ObsConfig;
use dancemoe::placement::uniform;
use dancemoe::serve::RegionsScenario;

/// Re-assert the spill conservation equations directly on the report —
/// the suite must not trust `conservation_exact`'s own bookkeeping.
fn assert_conservation(report: &ChaosReport) {
    let r = &report.regions;
    let mut spilled_in_total = 0u64;
    for region in &r.regions {
        let g = &region.gateway;
        assert_eq!(
            g.offered,
            (g.admitted - region.spilled_in)
                + (g.shed - region.spill_shed)
                + region.spilled_out,
            "{}: offered must partition into local admits, local sheds \
             and forwards",
            region.name
        );
        assert_eq!(g.forwarded_in, region.spilled_in, "{}", region.name);
        assert_eq!(
            g.serve.records.len() as u64,
            g.admitted,
            "{}: admitted requests must complete exactly once",
            region.name
        );
        spilled_in_total += region.spilled_in;
    }
    assert_eq!(r.offered, r.admitted + r.shed);
    assert_eq!(
        r.spilled,
        spilled_in_total + r.spill_shed,
        "every forward resolves to a peer admission or an origin shed"
    );
    assert_eq!(r.completed, r.admitted);
    assert!(report.conservation_exact, "report must agree with the books");
    assert!(report.ledger_balanced, "reservations must balance to zero");
}

/// The property-suite scenario: the canonical chaos base (autoscale on,
/// 15 s control interval) on a shorter horizon so randomized schedules
/// stay cheap while still leaving post-rejoin room for recovery.
fn short_base(seed: u64) -> RegionsScenario {
    RegionsScenario {
        autoscale: true,
        interval_s: 15.0,
        horizon_s: 240.0,
        seed,
        ..RegionsScenario::default()
    }
}

// ---- satellite 1: conservation + ledger across every fault class ------

#[test]
fn randomized_crash_only_schedules_conserve_and_recover() {
    for seed in [5u64, 23] {
        let base = short_base(seed);
        let schedule = FaultSchedule::random(
            ChaosClass::CrashOnly,
            seed,
            base.horizon_s,
            base.num_regions,
            3,
            base.interval_s,
        );
        let report = ChaosScenario { base, schedule }.run();
        assert!(report.regions.offered > 0);
        assert!(report.crashes >= 1, "seed {seed}: schedule must crash");
        assert!(
            report.recovery_complete,
            "seed {seed}: every crash must recover inside the horizon"
        );
        assert_conservation(&report);
    }
}

#[test]
fn randomized_partition_only_schedules_conserve() {
    for seed in [5u64, 23] {
        let base = short_base(seed);
        let schedule = FaultSchedule::random(
            ChaosClass::PartitionOnly,
            seed,
            base.horizon_s,
            base.num_regions,
            3,
            base.interval_s,
        );
        let report = ChaosScenario { base, schedule }.run();
        assert!(report.regions.offered > 0);
        assert_eq!(report.crashes, 0);
        assert!(report.recovery_complete, "vacuously true without crashes");
        assert_eq!(report.max_recovery_s, -1.0);
        assert_conservation(&report);
    }
}

#[test]
fn randomized_mixed_schedules_conserve_and_recover() {
    for seed in [5u64, 23] {
        let base = short_base(seed);
        let schedule = FaultSchedule::random(
            ChaosClass::Mixed,
            seed,
            base.horizon_s,
            base.num_regions,
            3,
            base.interval_s,
        );
        let report = ChaosScenario { base, schedule }.run();
        assert!(report.regions.offered > 0);
        assert!(report.crashes >= 1);
        assert!(report.recovery_complete, "seed {seed}");
        assert_conservation(&report);
    }
}

#[test]
fn crash_racing_scale_out_copies_conserves_the_ledger() {
    for seed in [5u64, 23] {
        let base = short_base(seed);
        let schedule = FaultSchedule::random(
            ChaosClass::CrashRace,
            seed,
            base.horizon_s,
            base.num_regions,
            3,
            base.interval_s,
        );
        let report = ChaosScenario { base, schedule }.run();
        assert!(report.regions.offered > 0);
        assert!(report.crashes >= 1);
        assert!(report.recovery_complete, "seed {seed}");
        // the whole point of the class: a crash landing just after a
        // boundary (while flash-crowd-provoked copies may be in flight)
        // still refunds every reservation
        assert_conservation(&report);
    }
}

// ---- satellite 1 (cont.): byte-identical replay ------------------------

#[test]
fn chaos_replay_is_byte_identical_across_seeds() {
    for seed in [3u64, 11] {
        let a = ChaosScenario::canonical(seed).run();
        let b = ChaosScenario::canonical(seed).run();
        assert_eq!(
            bench_file_json(&a).pretty(),
            bench_file_json(&b).pretty(),
            "seed {seed}: same seed + schedule must serialize \
             byte-identically"
        );
    }
}

#[test]
fn empty_schedule_matches_the_plain_regions_run() {
    let scenario = RegionsScenario {
        horizon_s: 200.0,
        seed: 9,
        ..RegionsScenario::default()
    };
    let plain = scenario.build().run();
    let chaos = scenario.build().run_chaos(&FaultSchedule::default());
    // the chaos machinery must be a no-op when no faults are scheduled
    assert_eq!(plain.offered, chaos.regions.offered);
    assert_eq!(plain.admitted, chaos.regions.admitted);
    assert_eq!(plain.shed, chaos.regions.shed);
    assert_eq!(plain.spilled, chaos.regions.spilled);
    assert_eq!(plain.p50_s.to_bits(), chaos.regions.p50_s.to_bits());
    assert_eq!(plain.p99_s.to_bits(), chaos.regions.p99_s.to_bits());
    assert!(chaos.faults.is_empty());
    assert_eq!(chaos.crashes, 0);
    assert_eq!(chaos.max_recovery_s, -1.0);
    assert!(chaos.recovery_complete);
    assert_conservation(&chaos);
}

#[test]
fn canonical_run_recovers_and_passes_every_verdict() {
    let report = ChaosScenario::canonical(0).run();
    assert!(report.crashes >= 1, "canonical schedule crashes r0s1");
    assert!(report.recoveries >= 1, "emergency re-covers must land");
    assert!(report.recovery_complete);
    assert!(
        report.max_recovery_s > 0.0,
        "a real crash recovery takes virtual time"
    );
    assert!(report.ok(), "the bench/CI pass condition");
    assert_conservation(&report);
    // the crash fault's row carries the recovery decomposition
    let crash = report
        .faults
        .iter()
        .find(|f| f.label.starts_with("crash_"))
        .expect("canonical schedule has a crash fault");
    assert!(crash.recovery_s > 0.0);
    assert!(crash.detect_s >= 0.0);
    assert!(crash.recopy_s >= 0.0);
    assert!(crash.recovery_s >= crash.detect_s);
}

// ---- satellite 2: the copy-races-crash ledger regression ---------------

/// Trimmed topology with proportionally tight GPU memory (the
/// autoscale-suite preset), so replica placement decisions are real.
fn small_tight() -> (ModelConfig, ClusterConfig) {
    let mut m = ModelConfig::mixtral_8x7b_sim();
    m.num_layers = 4;
    let mut c = ClusterConfig::edge_testbed_3_for(&m);
    let slots = (m.total_experts() as f64 * 1.3 / 4.0).ceil() as u64;
    for s in &mut c.servers {
        for g in &mut s.gpus {
            g.mem_bytes = m.expert_bytes * slots;
        }
    }
    (m, c)
}

#[test]
fn scale_out_copy_racing_a_crash_refunds_exactly_once() {
    let (m, c) = small_tight();
    let mut engine = Engine::new(
        &m,
        &c,
        uniform::place(&m, &c),
        EngineConfig::default(),
        CostModel::default(),
    );
    let mut coord = Coordinator::new(&m, &c, CoordinatorConfig::default());
    let (l, e) = (0, 0);
    let src = engine.placement.owners_ref(l, e)[0].0;
    let dst = (0..3)
        .find(|&s| !engine.placement.server_holds(s, l, e))
        .unwrap();
    assert!(coord.ledger.try_reserve(
        &engine.placement,
        dst,
        0,
        m.expert_bytes
    ));
    coord.recover_pending.push((l, e, dst, 0));
    assert_eq!(coord.ledger.reserved(dst, 0), m.expert_bytes);

    let apply_at = engine.schedule_scale_out(l, e, dst, 0, src).unwrap();
    // the destination dies while the weights are on the wire
    engine.schedule_server_crash(apply_at * 0.5, dst);
    engine.run_until(apply_at + 1.0);

    let completions = engine.take_scale_completions();
    let outs: Vec<_> = completions
        .iter()
        .filter(|ev| ev.kind == ScaleKind::Out)
        .collect();
    assert_eq!(outs.len(), 1, "the in-flight copy still completes");
    assert!(
        !outs[0].applied,
        "a copy landing on a dead server must not apply"
    );
    assert!(
        !engine.placement.server_holds(dst, l, e),
        "no routable phantom replica on the dead server"
    );
    engine.placement.validate().unwrap();

    coord.fold_completions(&completions);
    assert_eq!(
        coord.ledger.reserved(dst, 0),
        0,
        "the reservation is refunded exactly once"
    );
    assert!(coord.recover_pending.is_empty());

    // replaying the same completions must not refund a second time
    // (saturating release would mask a double-refund bug; the pending
    // entry being gone is the real guard)
    coord.fold_completions(&completions);
    assert_eq!(coord.ledger.reserved(dst, 0), 0, "no double refund");
}

// ---- satellite 3: fault-triggered flight-dump edge cases ---------------

fn bare_engine() -> Engine {
    let m = ModelConfig::tiny();
    let c = ClusterConfig::edge_testbed_3_for(&m);
    Engine::new(
        &m,
        &c,
        uniform::place(&m, &c),
        EngineConfig::default(),
        CostModel::default(),
    )
}

#[test]
fn crash_triggers_exactly_one_dump_ending_at_the_fault_time() {
    let mut engine = bare_engine();
    engine.obs.enable(ObsConfig::default());
    engine.schedule_server_crash(10.0, 1);
    engine.run_until(50.0);
    assert_eq!(engine.obs.dumps.len(), 1, "one crash, one dump");
    let dump = &engine.obs.dumps[0];
    assert_eq!(dump.reason, "fault_crash");
    assert_eq!(dump.t_s, 10.0, "dump taken at the fault instant");
    assert!(!dump.events.is_empty(), "the fault span itself is captured");
    for ev in &dump.events {
        assert!(
            ev.t_s <= dump.t_s + 1e-9,
            "ring contents must end at the fault timestamp"
        );
    }
}

#[test]
fn crashing_an_already_dead_server_does_not_dump_again() {
    let mut engine = bare_engine();
    engine.obs.enable(ObsConfig::default());
    engine.schedule_server_crash(10.0, 1);
    engine.schedule_server_crash(20.0, 1); // no-op: already dead
    engine.run_until(50.0);
    assert_eq!(
        engine.obs.dumps.len(),
        1,
        "a crash on a dead server is not a new fault event"
    );
    assert_eq!(engine.crashes, 1);
}

#[test]
fn rejoin_then_crash_dumps_once_per_real_fault() {
    let mut engine = bare_engine();
    engine.obs.enable(ObsConfig::default());
    engine.schedule_server_crash(10.0, 1);
    engine.schedule_server_rejoin(20.0, 1);
    engine.schedule_server_crash(30.0, 1);
    engine.run_until(50.0);
    assert_eq!(engine.obs.dumps.len(), 2, "two real crashes, two dumps");
    assert_eq!(engine.crashes, 2);
    assert_eq!(engine.obs.dumps[0].t_s, 10.0);
    assert_eq!(engine.obs.dumps[1].t_s, 30.0);
}

#[test]
fn dump_cap_overflow_is_surfaced_not_silent() {
    let mut engine = bare_engine();
    engine.obs.enable(ObsConfig {
        max_flight_dumps: 1,
        ..ObsConfig::default()
    });
    engine.schedule_server_crash(10.0, 0);
    engine.schedule_server_crash(20.0, 1);
    engine.run_until(50.0);
    assert_eq!(engine.obs.dumps.len(), 1, "cap keeps the first dump");
    assert!(
        engine.obs.dumps_dropped >= 1,
        "the dropped dump must be counted, not silently lost"
    );
}
