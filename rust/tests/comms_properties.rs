//! End-to-end properties of the communication-cost accounting layer:
//! byte-attribution exactness (flat network, topology-priced merged
//! cluster, inter-region mesh), result-neutrality of the traced
//! slices, and the decision payback ledger's row stream.

use dancemoe::config::{ClusterConfig, ModelConfig, WorkloadConfig};
use dancemoe::coordinator::CoordinatorConfig;
use dancemoe::obs::{
    CommsReport, ObsConfig, TransferPurpose, NUM_PURPOSES,
};
use dancemoe::placement::uniform;
use dancemoe::serve::{Gateway, GatewayConfig, RegionsScenario};
use dancemoe::util::json::Json;

/// The canonical migration scenario (the run
/// `tests/gateway_integration.rs` locks adoption on): 4-layer mixtral,
/// 3-server edge preset, home routing, uniform start, online stats only.
fn migration_gateway(migrate: bool, seed: u64) -> Gateway {
    let mut m = ModelConfig::mixtral_8x7b_sim();
    m.num_layers = 4;
    let c = ClusterConfig::edge_testbed_3_for(&m);
    let w = WorkloadConfig::bigbench(5.0);
    Gateway::new(
        &m,
        &c,
        &w,
        uniform::place(&m, &c),
        GatewayConfig {
            horizon_s: 480.0,
            locality_routing: false,
            seed,
            ..GatewayConfig::default()
        },
        CoordinatorConfig {
            interval_s: 60.0,
            migrate,
            seed,
            ..CoordinatorConfig::default()
        },
    )
}

/// Re-sum the (src, dst, purpose) link matrix in flat traversal order;
/// the result must reproduce the store's totals **bit for bit** —
/// skipped all-zero links contribute exactly 0.0, so the floating-point
/// addition sequence is identical to the store's own.
fn assert_exact(comms: &CommsReport, label: &str) {
    let mut total = 0.0f64;
    let mut per_purpose = [0.0f64; NUM_PURPOSES];
    for (_, _, by) in &comms.links {
        for (p, b) in by.iter().enumerate() {
            total += b;
            per_purpose[p] += b;
        }
    }
    assert_eq!(
        total.to_bits(),
        comms.total_bytes.to_bits(),
        "{label}: links must re-sum to total_bytes exactly \
         ({total} vs {})",
        comms.total_bytes
    );
    for p in TransferPurpose::ALL {
        let i = p.index();
        assert_eq!(
            per_purpose[i].to_bits(),
            comms.purpose_bytes[i].to_bits(),
            "{label}: {} links must re-sum to the purpose total exactly",
            p.name()
        );
    }
}

#[test]
fn flat_gateway_attribution_is_exact() {
    let mut gw = migration_gateway(true, 23);
    let report = gw.run();
    assert!(report.comms.total_bytes > 0.0, "remote traffic must flow");
    assert_exact(&report.comms, "flat gateway");
    // migration weight copies ride PCIe, never the request network
    assert_eq!(
        report.comms.purpose_bytes[TransferPurpose::MigrationCopy.index()],
        0.0
    );
    assert!(report.migrations > 0, "the canonical scenario migrates");
    assert!(
        report.comms.pcie_copy_bytes > 0.0,
        "adopted migrations must stage weight bytes over PCIe"
    );
    // spill is a regions-mode purpose; a single gateway never books it
    assert_eq!(
        report.comms.purpose_bytes[TransferPurpose::RegionSpill.index()],
        0.0
    );
}

#[test]
fn tiered_cache_attribution_is_exact_and_conserved() {
    // The expert cache's host tier books its staging traffic under the
    // prefetch_copy purpose: the link matrix still re-sums bit-exactly
    // with the sixth purpose in play, the engine's cache counters agree
    // with the network account bit for bit, and a zero host budget books
    // no prefetch bytes at all.
    let build = |host_experts: u64| {
        let mut m = ModelConfig::deepseek_v2_lite_sim();
        m.num_layers = 4;
        let mut c = ClusterConfig::edge_testbed_3_for(&m);
        for s in &mut c.servers {
            s.host_mem_bytes = host_experts * m.expert_bytes;
        }
        let w = WorkloadConfig::bigbench(5.0);
        Gateway::new(
            &m,
            &c,
            &w,
            uniform::place(&m, &c),
            GatewayConfig {
                horizon_s: 240.0,
                profile: dancemoe::serve::ArrivalProfile::Bursty {
                    factor: 6.0,
                    burst_s: 30.0,
                    period_s: 120.0,
                },
                seed: 7,
                ..GatewayConfig::default()
            },
            CoordinatorConfig {
                interval_s: 15.0,
                migrate: false,
                seed: 7,
                // EWMA-only: feeds the cache pass's load signal, never
                // adds or drains replicas itself
                autoscale: Some(dancemoe::autoscale::AutoscaleConfig {
                    hi_ratio: f64::INFINITY,
                    util_hi_tps: f64::INFINITY,
                    min_load_tps: 1.0,
                    ..dancemoe::autoscale::AutoscaleConfig::default()
                }),
                ..CoordinatorConfig::default()
            },
        )
    };
    let mut tiered = build(16);
    let report = tiered.run();
    assert_exact(&report.comms, "tiered gateway");
    let pf = TransferPurpose::PrefetchCopy.index();
    assert!(
        report.comms.purpose_bytes[pf] > 0.0,
        "the burst onsets must trigger prefetches"
    );
    assert_eq!(
        report.comms.purpose_bytes[pf].to_bits(),
        report.cache.prefetch_bytes.to_bits(),
        "network account and cache counters must agree on prefetch bytes"
    );
    assert!(report.cache.host_hits > 0, "staged experts must get hits");
    // every host hit and demotion moves weights over PCIe (promotions on
    // top), never over the request network
    let eb = tiered.engine.model.expert_bytes as f64;
    assert!(
        report.comms.pcie_copy_bytes
            >= report.cache.host_hits as f64 * eb
                + report.cache.demotion_bytes,
        "host-tier PCIe traffic must be accounted"
    );

    let mut two_state = build(0);
    let base = two_state.run();
    assert_exact(&base.comms, "two-state gateway");
    assert_eq!(
        base.comms.purpose_bytes[pf], 0.0,
        "no host budget, no prefetch traffic"
    );
    assert_eq!(base.cache.host_hits, 0);
    assert_eq!(base.cache.prefetches, 0);
}

#[test]
fn topology_priced_attribution_is_exact() {
    // the single-global-gateway baseline: one engine over the merged
    // cluster with cross-region links priced by the topology
    let global = RegionsScenario {
        horizon_s: 200.0,
        seed: 7,
        ..RegionsScenario::default()
    }
    .build_global();
    let mut gw = global;
    let report = gw.run();
    assert!(report.comms.total_bytes > 0.0);
    assert_exact(&report.comms, "topology-priced global gateway");
}

#[test]
fn mesh_attribution_is_exact_and_spill_only() {
    let mut multi = RegionsScenario {
        horizon_s: 200.0,
        seed: 5,
        ..RegionsScenario::default()
    }
    .build();
    let report = multi.run();
    assert!(report.spilled > 0, "the staggered scenario must spill");
    // the inter-region mesh re-sums exactly, and spill forwards are its
    // only traffic
    let mut total = 0.0f64;
    for (src, dst, by) in &report.mesh_links {
        assert_ne!(src, dst, "mesh links are cross-region");
        for p in TransferPurpose::ALL {
            if p == TransferPurpose::RegionSpill {
                assert!(by[p.index()] > 0.0);
            } else {
                assert_eq!(by[p.index()], 0.0);
            }
        }
        total += by.iter().sum::<f64>();
    }
    assert!(total > 0.0);
    assert_eq!(total.to_bits(), report.mesh_bytes.to_bits());
    // every regional request network re-sums exactly too
    for region in &report.regions {
        assert_exact(&region.gateway.comms, &region.name);
    }
}

#[test]
fn traced_slices_match_untraced_bytes() {
    // tracing is result-neutral on the byte axis: the purpose totals of
    // a traced run are bit-identical to the untraced run, and the traced
    // per-expert account covers the request-path purposes exactly (up to
    // summation order).
    let plain = migration_gateway(true, 23).run();
    let mut traced_gw = migration_gateway(true, 23);
    traced_gw.enable_obs(ObsConfig::default());
    let traced = traced_gw.run();
    for p in 0..NUM_PURPOSES {
        assert_eq!(
            plain.comms.purpose_bytes[p].to_bits(),
            traced.comms.purpose_bytes[p].to_bits(),
            "tracing must not change purpose totals"
        );
    }
    assert!(plain.comms.account.is_empty(), "untraced runs keep no slices");
    assert!(!traced.comms.account.is_empty());
    for p in [TransferPurpose::ExpertCall, TransferPurpose::ResultReturn] {
        let account: f64 = traced
            .comms
            .account
            .per_expert
            .values()
            .map(|by| by[p.index()])
            .sum();
        let net = traced.comms.purpose_bytes[p.index()];
        assert!(
            (account - net).abs() <= 1e-9 * net.max(1.0),
            "traced {} slices must cover the network total \
             ({account} vs {net})",
            p.name()
        );
    }
}

#[test]
fn payback_ledger_credits_migrations_and_emits_rows() {
    let mut gw = migration_gateway(true, 23);
    gw.enable_obs(ObsConfig::default());
    let report = gw.run();
    assert!(report.migrations > 0);
    let ledger = &report.comms.ledger;
    assert!(
        !ledger.decisions.is_empty(),
        "adopted migrations must open payback records"
    );
    for d in &ledger.decisions {
        assert!(d.cost_bytes >= 0.0);
        assert!(d.credited_bytes >= 0.0);
        if let Some(dt) = d.payback_s() {
            assert!(dt >= 0.0, "payback cannot precede the decision");
        }
    }
    // the metrics stream carries the new row kinds, schema-stamped and
    // clock-ordered
    let metrics = gw.metrics_jsonl();
    let mut kinds = std::collections::BTreeSet::new();
    let mut last = f64::NEG_INFINITY;
    for line in metrics.lines() {
        let row = Json::parse(line).expect("row parses");
        let t = row.get("t_s").and_then(|v| v.as_f64()).unwrap();
        assert!(t >= last, "rows must stay in virtual-clock order");
        last = t;
        assert_eq!(
            row.get("schema").and_then(|v| v.as_f64()),
            Some(3.0),
            "every row carries the schema version"
        );
        if let Some(Json::Str(k)) = row.get("kind") {
            kinds.insert(k.clone());
        }
    }
    for required in ["comms_window", "placement_window", "decision"] {
        assert!(
            kinds.contains(required),
            "metrics stream must emit {required} rows (saw {kinds:?})"
        );
    }
}

#[test]
fn unpaid_decision_triggers_flight_dump() {
    // zero patience: any decision with an upfront cost goes overdue at
    // the next interval tick, so the flight recorder must fire
    let mut gw = migration_gateway(true, 23);
    gw.enable_obs(ObsConfig {
        payback_patience_s: 0.0,
        ..ObsConfig::default()
    });
    let report = gw.run();
    assert!(report.migrations > 0);
    assert!(
        gw.engine
            .obs
            .dumps
            .iter()
            .any(|d| d.reason == "unpaid_decision"),
        "an overdue decision must dump the flight ring"
    );
    assert!(
        report.comms.ledger.decisions.iter().any(|d| d.dumped),
        "the overdue record must be marked dumped"
    );
}
