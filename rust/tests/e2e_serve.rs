//! End-to-end: the routed sparse execution the engine models must be
//! numerically identical to the dense-MoE oracle, and the full pipeline
//! (placement → engine → report) must hold together on the real testbed
//! configuration.

use std::path::PathBuf;

use dancemoe::config::{ClusterConfig, ModelConfig, WorkloadConfig};
use dancemoe::engine::World;
use dancemoe::placement::PlacementAlgo;
use dancemoe::runtime::{forward, weights, Runtime};

fn artifacts_dir() -> PathBuf {
    Runtime::default_dir()
}

#[test]
fn routed_forward_matches_dense_oracle() {
    let dir = artifacts_dir();
    if !Runtime::available(&dir) {
        eprintln!("SKIP: no artifacts (build with `python -m compile.aot`)");
        return;
    }
    let model = ModelConfig::tiny();
    let mut rt = Runtime::open(&dir).unwrap();
    // one layer: mixer → (sparse routed MoE) vs (dense oracle artifact)
    let tokens = 8;
    let x = weights::input_tokens(&model, 99, tokens);
    // mixer output via the nonmoe artifact (same path forward() takes)
    let lw = weights::layer_weights(&model, 0);
    let hm = rt
        .run_f32(
            "nonmoe_h64_b8",
            &[(&x, &[8, 64]), (&lw.wm, &[64, 64]), (&lw.scale, &[64])],
        )
        .unwrap();

    // dense oracle of the MoE layer on hm
    let dense =
        forward::dense_layer_oracle(&mut rt, &model, &hm, tokens, 0).unwrap();

    // sparse routed execution of the same layer (replicating forward()'s
    // inner loop for layer 0 only)
    let probs = rt
        .run_f32("gate_h64_e8_b8", &[(&hm, &[8, 64]), (&lw.wg, &[64, 8])])
        .unwrap();
    let h = model.hidden;
    let mut routed = vec![0.0f32; tokens * h];
    for t in 0..tokens {
        let row = &probs[t * 8..(t + 1) * 8];
        for (e, w) in forward::topk_renorm(row, model.top_k) {
            let ew = weights::expert_weights(&model, 0, e);
            let mut xt = vec![0.0f32; h];
            xt.copy_from_slice(&hm[t * h..(t + 1) * h]);
            let xp = dancemoe::runtime::pad_rows(&xt, 1, h, 1);
            let y = rt
                .run_f32(
                    "expert_h64_f128_b1",
                    &[
                        (&xp, &[1, 64]),
                        (&ew.w1, &[64, 128]),
                        (&ew.w3, &[64, 128]),
                        (&ew.w2, &[128, 64]),
                    ],
                )
                .unwrap();
            for d in 0..h {
                routed[t * h + d] += w * y[d];
            }
        }
    }
    let maxd = dense
        .iter()
        .zip(&routed)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        maxd < 5e-5,
        "sparse routed vs dense oracle: max abs diff {maxd}"
    );
}

#[test]
fn full_forward_runs_all_layers() {
    let dir = artifacts_dir();
    if !Runtime::available(&dir) {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let model = ModelConfig::tiny();
    let mut rt = Runtime::open(&dir).unwrap();
    let tokens = 8;
    let x = weights::input_tokens(&model, 5, tokens);
    let y = forward::forward(&mut rt, &model, &x, tokens).unwrap();
    assert_eq!(y.len(), tokens * model.hidden);
    assert!(y.iter().all(|v| v.is_finite()));
    // the stack must actually transform the input
    let diff: f32 = y
        .iter()
        .zip(&x)
        .map(|(a, b)| (a - b).abs())
        .sum::<f32>();
    assert!(diff > 1.0, "forward was a no-op?");
    // deterministic
    let y2 = forward::forward(&mut rt, &model, &x, tokens).unwrap();
    assert_eq!(y, y2);
}

#[test]
fn padding_does_not_change_results() {
    let dir = artifacts_dir();
    if !Runtime::available(&dir) {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let model = ModelConfig::tiny();
    let mut rt = Runtime::open(&dir).unwrap();
    // 3 tokens forward (padded to bucket 8 internally) must equal the first
    // 3 rows of an... independent run with the same 3 tokens. Stronger: the
    // per-expert group padding must not leak padded rows into real outputs.
    let x3 = weights::input_tokens(&model, 6, 3);
    let y3 = forward::forward(&mut rt, &model, &x3, 3).unwrap();
    assert_eq!(y3.len(), 3 * model.hidden);
    assert!(y3.iter().all(|v| v.is_finite()));
}

#[test]
fn simulated_testbed_end_to_end() {
    // no artifacts needed: the virtual-time pipeline on the paper testbed
    let model = ModelConfig::deepseek_v2_lite_sim();
    let cluster = ClusterConfig::edge_testbed_3_for(&model);
    let workload = WorkloadConfig::bigbench(10.0);
    let mut world = World::build(&model, &cluster, &workload, 1);
    let ours = world.place();
    ours.validate().unwrap();
    let rep_ours = world.serve(&ours, 20);
    let uni = PlacementAlgo::Uniform.compute(
        &model,
        &cluster,
        world.stats(),
        1,
    );
    let rep_uni = world.serve(&uni, 20);
    assert_eq!(rep_ours.records.len(), 60);
    assert!(
        rep_ours.avg_latency() < rep_uni.avg_latency(),
        "DanceMoE {:.2}s must beat Uniform {:.2}s on the testbed",
        rep_ours.avg_latency(),
        rep_uni.avg_latency()
    );
    assert!(rep_ours.local_ratio() > rep_uni.local_ratio());
}
