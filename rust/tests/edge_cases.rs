//! Edge-case and failure-injection tests: degenerate workloads, extreme
//! clusters, JSON fuzzing, and hardware-speed perturbation mid-fleet.

use dancemoe::config::{
    ClusterConfig, GpuConfig, ModelConfig, ServerConfig, StreamConfig,
    TaskKind, WorkloadConfig,
};
use dancemoe::engine::{warm_stats, CostModel, Engine, EngineConfig, Mode};
use dancemoe::placement::PlacementAlgo;
use dancemoe::trace::{Trace, TraceGenerator};
use dancemoe::util::json::Json;
use dancemoe::util::prop::{assert_prop, check};

fn tiny() -> ModelConfig {
    ModelConfig::tiny() // 4 layers × 8 experts, top-2
}

fn run(
    m: &ModelConfig,
    c: &ClusterConfig,
    w: &WorkloadConfig,
    trace: &Trace,
    mode: Mode,
) -> dancemoe::engine::ServeReport {
    let stats = warm_stats(m, w);
    let placement = PlacementAlgo::DanceMoE.compute(m, c, &stats, 1);
    let mut eng = Engine::new(
        m,
        c,
        placement,
        EngineConfig {
            mode,
            seed: 1,
            ..EngineConfig::default()
        },
        CostModel::default(),
    );
    eng.push_trace(trace);
    eng.run();
    std::mem::replace(
        &mut eng.report,
        dancemoe::engine::ServeReport::new(c.num_servers(), 60.0),
    )
}

#[test]
fn empty_trace_is_a_noop() {
    let m = tiny();
    let c = ClusterConfig::edge_testbed_3_for(&m);
    let w = WorkloadConfig::bigbench(10.0);
    let rep = run(&m, &c, &w, &Trace::default(), Mode::Collaborative);
    assert_eq!(rep.records.len(), 0);
    assert_eq!(rep.makespan_s, 0.0);
}

#[test]
fn zero_output_tokens_prefill_only() {
    let m = tiny();
    let c = ClusterConfig::edge_testbed_3_for(&m);
    let mut w = WorkloadConfig::bigbench(10.0);
    for s in &mut w.streams {
        s.output_tokens = 0;
    }
    let trace = TraceGenerator::new(&m, &w, 3).gen_count(5);
    let rep = run(&m, &c, &w, &trace, Mode::Collaborative);
    assert_eq!(rep.records.len(), 15);
    assert!(rep.records.iter().all(|r| r.latency_s > 0.0));
}

#[test]
fn single_server_cluster_never_remote() {
    let m = tiny();
    let c = ClusterConfig {
        name: "solo".into(),
        servers: vec![ServerConfig {
            name: "only".into(),
            gpus: vec![GpuConfig {
                mem_bytes: m.expert_bytes * m.total_experts() as u64 * 2,
                flops: 100e12,
                pcie_bps: 16e9,
            }],
            host_mem_bytes: 0,
        }],
        bandwidth_bps: 500e6,
        rtt_s: 0.002,
    };
    let w = WorkloadConfig {
        name: "solo".into(),
        streams: vec![StreamConfig {
            task: TaskKind::Arithmetic,
            mean_interarrival_s: 5.0,
            mean_prompt_tokens: 32,
            output_tokens: 4,
        }],
    };
    let trace = TraceGenerator::new(&m, &w, 5).gen_count(10);
    let rep = run(&m, &c, &w, &trace, Mode::Collaborative);
    assert_eq!(rep.records.len(), 10);
    assert_eq!(rep.local_ratio(), 1.0);
    assert_eq!(rep.net_bytes, 0.0);
}

#[test]
fn top1_and_full_topk_routing() {
    // top_k = 1 (Switch-style) and top_k = E (dense) both serve correctly
    let c = ClusterConfig::edge_testbed_3_for(&tiny());
    for k in [1usize, 8] {
        let mut m = tiny();
        m.top_k = k;
        let w = WorkloadConfig::bigbench(10.0);
        let trace = TraceGenerator::new(&m, &w, 7).gen_count(5);
        let rep = run(&m, &c, &w, &trace, Mode::Collaborative);
        assert_eq!(rep.records.len(), 15, "top_k={k}");
        // token invocations per request = tokens × k × layers
        for r in &rep.records {
            let total =
                r.local_token_invocations + r.remote_token_invocations;
            assert!(total > 0.0);
        }
    }
}

#[test]
fn slow_gpu_server_becomes_bottleneck() {
    // failure injection: one server's GPU degrades 10× (thermal throttling,
    // contention, ...). Its latency must rise relative to the healthy run.
    let m = tiny();
    let w = WorkloadConfig::bigbench(3.0);
    let trace = TraceGenerator::new(&m, &w, 11).gen_count(30);
    let healthy = ClusterConfig::edge_testbed_3_for(&m);
    let mut degraded = healthy.clone();
    degraded.servers[1].gpus[0].flops /= 10.0;
    // also slow its expert dispatch (overhead dominates tiny models)
    let h = run(&m, &healthy, &w, &trace, Mode::Collaborative);
    let d = run(&m, &degraded, &w, &trace, Mode::Collaborative);
    assert!(
        d.server_avg_latency(1) >= h.server_avg_latency(1),
        "degraded {:.4} vs healthy {:.4}",
        d.server_avg_latency(1),
        h.server_avg_latency(1)
    );
}

#[test]
fn extreme_bandwidth_bounds() {
    let m = tiny();
    let w = WorkloadConfig::bigbench(5.0);
    let trace = TraceGenerator::new(&m, &w, 13).gen_count(15);
    let mut crawl = ClusterConfig::edge_testbed_3_for(&m);
    crawl.bandwidth_bps = 1e6; // 1 Mbps
    let mut fiber = ClusterConfig::edge_testbed_3_for(&m);
    fiber.bandwidth_bps = 100e9; // 100 Gbps
    let slow = run(&m, &crawl, &w, &trace, Mode::Collaborative);
    let fast = run(&m, &fiber, &w, &trace, Mode::Collaborative);
    assert!(slow.avg_latency() >= fast.avg_latency());
    assert!(fast.avg_latency().is_finite());
}

#[test]
fn prop_json_fuzz_never_panics_and_roundtrips() {
    // generated JSON values always serialize → parse → equal
    check("json roundtrip", 150, |g| {
        fn gen_value(g: &mut dancemoe::util::prop::Gen, depth: usize) -> Json {
            let choice = g.usize_in(0, if depth > 2 { 3 } else { 5 });
            match choice {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f64_in(-1e9, 1e9) * 100.0).round() / 100.0),
                3 => {
                    let n = g.usize_in(0, 8);
                    Json::Str(
                        (0..n)
                            .map(|i| {
                                char::from(
                                    b'a' + ((i * 7 + n) % 26) as u8,
                                )
                            })
                            .chain("\"\\\n é".chars())
                            .collect(),
                    )
                }
                4 => Json::Arr(
                    (0..g.usize_in(0, 4))
                        .map(|_| gen_value(g, depth + 1))
                        .collect(),
                ),
                _ => {
                    let mut obj = Json::obj();
                    for i in 0..g.usize_in(0, 4) {
                        obj.set(&format!("k{i}"), gen_value(g, depth + 1));
                    }
                    obj
                }
            }
        }
        let v = gen_value(g, 0);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| {
            panic!("reparse failed: {e} for {text}");
        });
        assert_prop(back == v, "roundtrip mismatch");
        // pretty form also reparses
        let back2 = Json::parse(&v.pretty()).unwrap();
        assert_prop(back2 == v, "pretty roundtrip mismatch");
    });
}

#[test]
fn prop_garbage_json_never_panics() {
    check("json garbage", 200, |g| {
        let len = g.usize_in(0, 40);
        let bytes: Vec<u8> = (0..len)
            .map(|_| {
                let printable = g.usize_in(32, 126) as u8;
                printable
            })
            .collect();
        let text = String::from_utf8_lossy(&bytes).to_string();
        let _ = Json::parse(&text); // must not panic, Ok or Err both fine
    });
}

#[test]
fn offload_cache_thrash_under_uniform_profile() {
    // A model much larger than the cache with uniform activations must
    // show a lower hit rate (higher latency) than a skewed one.
    let m = ModelConfig::mixtral_8x7b_sim();
    let c = ClusterConfig::edge_testbed_3_for(&m);
    let mk = |task: TaskKind| WorkloadConfig {
        name: "x".into(),
        streams: vec![
            StreamConfig {
                task,
                mean_interarrival_s: 15.0,
                mean_prompt_tokens: 64,
                output_tokens: 4,
            };
            3
        ],
    };
    // arithmetic has strongly-skewed layers; wikitext is its own mix — we
    // compare the same task against an artificially uniformized model by
    // raising top_k (more experts touched per token ⇒ more cache pressure)
    let w = mk(TaskKind::Arithmetic);
    let trace = TraceGenerator::new(&m, &w, 17).gen_count(15);
    let low_pressure = run(&m, &c, &w, &trace, Mode::Offload { lb: false });
    let mut m8 = m.clone();
    m8.top_k = 8;
    let trace8 = TraceGenerator::new(&m8, &w, 17).gen_count(15);
    let high_pressure =
        run(&m8, &c, &w, &trace8, Mode::Offload { lb: false });
    assert!(
        high_pressure.avg_latency() > low_pressure.avg_latency(),
        "top-8 {:.2}s should thrash more than top-2 {:.2}s",
        high_pressure.avg_latency(),
        low_pressure.avg_latency()
    );
}
