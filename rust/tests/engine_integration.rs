//! Integration tests over the discrete-event engine: conservation laws,
//! time monotonicity, queueing behaviour, and cross-mode comparisons.

use dancemoe::config::{ClusterConfig, ModelConfig, WorkloadConfig};
use dancemoe::engine::{
    warm_stats, CostModel, Engine, EngineConfig, Mode, ServeReport,
};
use dancemoe::placement::PlacementAlgo;
use dancemoe::trace::TraceGenerator;
use dancemoe::util::prop::{assert_prop, check};

fn small_model() -> ModelConfig {
    let mut m = ModelConfig::mixtral_8x7b_sim();
    m.num_layers = 6;
    m
}

fn run(
    model: &ModelConfig,
    workload: &WorkloadConfig,
    algo: PlacementAlgo,
    mode: Mode,
    n: usize,
    seed: u64,
) -> ServeReport {
    let cluster = ClusterConfig::edge_testbed_3_for(model);
    let stats = warm_stats(model, workload);
    let placement = algo.compute(model, &cluster, &stats, seed);
    let mut eng = Engine::new(
        model,
        &cluster,
        placement,
        EngineConfig {
            mode,
            seed,
            ..EngineConfig::default()
        },
        CostModel::default(),
    );
    let trace = TraceGenerator::new(model, workload, seed).gen_count(n);
    eng.push_trace(&trace);
    eng.run();
    std::mem::replace(&mut eng.report, ServeReport::new(3, 60.0))
}

#[test]
fn conservation_every_request_finishes_once() {
    let m = small_model();
    let w = WorkloadConfig::bigbench(8.0);
    let rep = run(&m, &w, PlacementAlgo::DanceMoE, Mode::Collaborative, 25, 3);
    assert_eq!(rep.records.len(), 75);
    let mut ids: Vec<usize> = rep.records.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 75, "duplicate completions");
}

#[test]
fn latency_decomposition_adds_up() {
    // local + remote token invocations per request = tokens × top_k × layers
    let m = small_model();
    let w = WorkloadConfig::bigbench(8.0);
    let rep = run(&m, &w, PlacementAlgo::Uniform, Mode::Collaborative, 10, 5);
    for r in &rep.records {
        let total = r.local_token_invocations + r.remote_token_invocations;
        assert!(total > 0.0);
        // every routed token appears exactly once per (layer, k-slot)
        let per_pass = m.top_k as f64 * m.num_layers as f64;
        let tokens = total / per_pass;
        assert!(
            tokens > 8.0,
            "request routed fewer tokens than the minimum prompt"
        );
    }
}

#[test]
fn heavier_load_increases_latency() {
    let m = small_model();
    let light = run(
        &m,
        &WorkloadConfig::bigbench(30.0),
        PlacementAlgo::DanceMoE,
        Mode::Collaborative,
        25,
        7,
    );
    let heavy = run(
        &m,
        &WorkloadConfig::bigbench(0.5),
        PlacementAlgo::DanceMoE,
        Mode::Collaborative,
        25,
        7,
    );
    assert!(
        heavy.avg_latency() > light.avg_latency(),
        "queueing must show: heavy {:.3}s vs light {:.3}s",
        heavy.avg_latency(),
        light.avg_latency()
    );
}

#[test]
fn lower_bandwidth_hurts_remote_heavy_placements() {
    let m = small_model();
    let w = WorkloadConfig::bigbench(10.0);
    let stats = warm_stats(&m, &w);
    let mut slow_cluster = ClusterConfig::edge_testbed_3_for(&m);
    slow_cluster.bandwidth_bps = 50e6; // 10× slower than the testbed
    let fast_cluster = ClusterConfig::edge_testbed_3_for(&m);
    let trace = TraceGenerator::new(&m, &w, 9).gen_count(15);
    let mut lat = Vec::new();
    for cluster in [&fast_cluster, &slow_cluster] {
        let placement =
            PlacementAlgo::Uniform.compute(&m, cluster, &stats, 9);
        let mut eng = Engine::new(
            &m,
            cluster,
            placement,
            EngineConfig {
                seed: 9,
                ..EngineConfig::default()
            },
            CostModel::default(),
        );
        eng.push_trace(&trace);
        eng.run();
        lat.push(eng.report.avg_latency());
    }
    assert!(
        lat[1] > lat[0] * 1.2,
        "slow net {:.2}s should clearly exceed fast net {:.2}s",
        lat[1],
        lat[0]
    );
}

#[test]
fn offload_thrash_vs_collaboration_table1_shape() {
    // Table I's core claim: collaboration beats per-server offloading under
    // imbalanced, skew-mismatched load.
    let m = ModelConfig::mixtral_8x7b_sim(); // full size for cache pressure
    let mut w = WorkloadConfig::bigbench(10.0);
    w.streams[0].mean_interarrival_s = 4.0;
    let offload = run(&m, &w, PlacementAlgo::Uniform, Mode::Offload { lb: false }, 15, 11);
    let collab = run(&m, &w, PlacementAlgo::Redundance, Mode::Collaborative, 15, 11);
    assert!(
        collab.avg_latency() < offload.avg_latency(),
        "collab {:.2}s vs offload {:.2}s",
        collab.avg_latency(),
        offload.avg_latency()
    );
}

#[test]
fn prop_engine_records_are_causal() {
    check("causal records", 15, |g| {
        let m = small_model();
        let w = WorkloadConfig::bigbench(g.f64_in(2.0, 20.0));
        let seed = g.usize_in(0, 500) as u64;
        let rep = run(&m, &w, PlacementAlgo::DanceMoE, Mode::Collaborative, 8, seed);
        for r in &rep.records {
            assert_prop(r.done_s >= r.arrival_s, "completion before arrival");
            assert_prop(r.latency_s >= 0.0, "negative latency");
        }
        // makespan is the max completion
        let max_done = rep
            .records
            .iter()
            .map(|r| r.done_s)
            .fold(0.0f64, f64::max);
        assert_prop(
            (rep.makespan_s - max_done).abs() < 1e-9,
            "makespan mismatch",
        );
    });
}

#[test]
fn gpu_utilization_accounting_consistent() {
    let m = small_model();
    let w = WorkloadConfig::bigbench(10.0);
    let rep = run(&m, &w, PlacementAlgo::DanceMoE, Mode::Collaborative, 20, 13);
    let busy: f64 = rep.gpu_busy_s.iter().sum();
    assert!(busy > 0.0);
    // busy time can't exceed makespan × #GPUs
    assert!(busy <= rep.makespan_s * 4.0 + 1e-6);
}
