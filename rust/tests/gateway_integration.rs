//! Integration tests for the online serving gateway: end-to-end runs on
//! the 3-server edge preset, convergence of online-driven migration
//! against offline warm-stats seeding, and backpressure under overload.

use dancemoe::config::{ClusterConfig, ModelConfig, WorkloadConfig};
use dancemoe::coordinator::CoordinatorConfig;
use dancemoe::engine::warm_stats;
use dancemoe::placement::{objective, uniform, PlacementAlgo};
use dancemoe::serve::{Gateway, GatewayConfig};

fn small() -> (ModelConfig, ClusterConfig, WorkloadConfig) {
    let mut m = ModelConfig::mixtral_8x7b_sim();
    m.num_layers = 4; // keep virtual-time runs fast
    let c = ClusterConfig::edge_testbed_3_for(&m);
    (m, c, WorkloadConfig::bigbench(5.0))
}

#[test]
fn gateway_end_to_end_on_edge_preset() {
    let (m, c, w) = small();
    let mut gw = Gateway::new(
        &m,
        &c,
        &w,
        uniform::place(&m, &c),
        GatewayConfig {
            horizon_s: 300.0,
            // home routing so each stream exercises its own server (with
            // locality routing a uniform start legitimately concentrates
            // traffic on the largest server)
            locality_routing: false,
            seed: 21,
            ..GatewayConfig::default()
        },
        CoordinatorConfig {
            interval_s: 60.0,
            seed: 21,
            ..CoordinatorConfig::default()
        },
    );
    let report = gw.run();
    // all three streams produced and served traffic
    assert!(report.offered > 30);
    assert_eq!(report.offered, report.admitted + report.shed);
    assert_eq!(report.serve.records.len() as u64, report.admitted);
    for n in 0..3 {
        assert!(
            report.serve.records.iter().any(|r| r.server == n),
            "server {n} served nothing"
        );
    }
    // the latency report is well-formed
    let p50 = report.latency_percentile(0.50);
    let p99 = report.latency_percentile(0.99);
    assert!(p50 > 0.0 && p50 <= p99);
    // stats-bus refreshes ran from online measurements
    assert!(report.refreshes >= 3);
}

#[test]
fn online_migration_converges_to_offline_seeding() {
    // Stationary workload, home routing (so the online activation stream
    // matches the offline expectation): migration driven purely by
    // online-collected stats must reach a placement as good — measured by
    // the paper's Eq. 2 objective under the true (warm) statistics — as
    // the offline pipeline seeded with those statistics up front.
    let (m, c, w) = small();
    let mut gw = Gateway::new(
        &m,
        &c,
        &w,
        uniform::place(&m, &c),
        GatewayConfig {
            horizon_s: 480.0,
            locality_routing: false,
            seed: 23,
            ..GatewayConfig::default()
        },
        CoordinatorConfig {
            interval_s: 60.0,
            seed: 23,
            ..CoordinatorConfig::default()
        },
    );
    let report = gw.run();
    assert!(
        report.migrations >= 1,
        "online stats must trigger at least one migration"
    );

    let warm = warm_stats(&m, &w);
    let offline = PlacementAlgo::DanceMoE.compute(&m, &c, &warm, 23);
    let online_ratio =
        objective::expected_local_ratio(&gw.engine.placement, &warm);
    let offline_ratio = objective::expected_local_ratio(&offline, &warm);
    let uniform_ratio =
        objective::expected_local_ratio(&uniform::place(&m, &c), &warm);
    assert!(
        online_ratio > uniform_ratio + 0.05,
        "online migration must beat the uniform start: \
         {online_ratio:.3} vs {uniform_ratio:.3}"
    );
    assert!(
        online_ratio >= offline_ratio - 0.05,
        "online-converged placement ({online_ratio:.3}) must match \
         offline warm-stats seeding ({offline_ratio:.3})"
    );
}

#[test]
fn migration_disabled_keeps_initial_placement() {
    let (m, c, w) = small();
    let initial = uniform::place(&m, &c);
    let mut gw = Gateway::new(
        &m,
        &c,
        &w,
        initial.clone(),
        GatewayConfig {
            horizon_s: 240.0,
            seed: 29,
            ..GatewayConfig::default()
        },
        CoordinatorConfig {
            interval_s: 60.0,
            migrate: false,
            ..CoordinatorConfig::default()
        },
    );
    let report = gw.run();
    assert_eq!(report.migrations, 0);
    assert_eq!(gw.engine.placement, initial);
    // refreshes still evaluated (observability), they just never adopt
    assert!(report.refreshes >= 2);
}

#[test]
fn locality_routing_does_not_lose_requests() {
    let (m, c, w) = small();
    let warm = warm_stats(&m, &w);
    // start from the activation-aware placement so locality routing has
    // real signal from t = 0
    let initial = PlacementAlgo::DanceMoE.compute(&m, &c, &warm, 31);
    let mut gw = Gateway::new(
        &m,
        &c,
        &w,
        initial,
        GatewayConfig {
            horizon_s: 240.0,
            seed: 31,
            ..GatewayConfig::default()
        },
        CoordinatorConfig {
            interval_s: 60.0,
            seed: 31,
            ..CoordinatorConfig::default()
        },
    );
    let report = gw.run();
    assert_eq!(report.offered, report.admitted + report.shed);
    assert_eq!(report.serve.records.len() as u64, report.admitted);
    // under the paper's placement + moderate load, locality routing keeps
    // most compute local
    assert!(
        report.serve.local_ratio() > 0.5,
        "local ratio {:.3}",
        report.serve.local_ratio()
    );
}

#[test]
fn overload_backpressure_bounds_admission() {
    let (m, c, _) = small();
    let w = WorkloadConfig::bigbench(0.05); // 20 req/s per server: overload
    let mut gw = Gateway::new(
        &m,
        &c,
        &w,
        uniform::place(&m, &c),
        GatewayConfig {
            horizon_s: 30.0,
            queue_cap: 16,
            max_inflight: 16,
            seed: 37,
            ..GatewayConfig::default()
        },
        CoordinatorConfig {
            interval_s: 15.0,
            ..CoordinatorConfig::default()
        },
    );
    let report = gw.run();
    assert!(report.shed > 0, "open-loop overload must shed");
    assert!(report.admitted < report.offered);
    // everything admitted still completes — bounded queues, not dropped work
    assert_eq!(report.serve.records.len() as u64, report.admitted);
    assert!(report.slo_violation_rate() > 0.0);
}
