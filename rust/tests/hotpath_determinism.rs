//! The hot-path overhaul's safety contract: **byte-identical results**.
//!
//! The optimized engine (slab event queue, packed queue keys, zero-alloc
//! layer passes, fused gate sampling, bitset placement, cached
//! earliest-GPU argmin) must produce exactly the results of the frozen
//! pre-overhaul implementation ([`dancemoe::engine::reference`]): the
//! same RNG draw sequence, the same event order, bit-identical reports.
//! This suite pins that equivalence three ways:
//!
//! 1. **sampler stream equivalence** — the fused zero-alloc gate sampler
//!    consumes the identical uniform stream and picks the identical
//!    experts as the reference implementation, including degenerate
//!    recorded profiles with fewer positive-weight experts than `k`;
//! 2. **engine equivalence** — offline trace runs (collaborative +
//!    offload modes, both model topologies, recorded-profile replays) and
//!    a gateway-style online script (staggered injection, segmented
//!    `run_until`, a mid-run migration and a scale-out/scale-in cycle)
//!    produce bitwise-equal reports, stats, placements and scale events
//!    on both engines, at multiple seeds;
//! 3. **serving-stack replay** — full `gateway`, `autoscale` and
//!    `tenants` runs serialize to byte-identical metric documents across
//!    repeated runs at 2 seeds each, so no nondeterminism (or
//!    iteration-order dependence) can hide above the engine either.
//!
//! Plus the slab's memory contract: the optimized engine's event storage
//! high-water is bounded by in-flight events, strictly below the
//! reference engine's grow-only event store on any long run.

use dancemoe::autoscale::AutoscaleConfig;
use dancemoe::config::{ClusterConfig, ModelConfig, TaskKind, WorkloadConfig};
use dancemoe::coordinator::CoordinatorConfig;
use dancemoe::engine::reference::{
    ref_sample_batch, ref_sample_batch_fast, RefEngine,
};
use dancemoe::engine::{
    warm_stats, CostModel, Engine, EngineConfig, Mode, ServeReport,
};
use dancemoe::moe::ActivationStats;
use dancemoe::placement::{uniform, Placement, PlacementAlgo};
use dancemoe::serve::tenant::{bench_file_json, bursty_comparison};
use dancemoe::serve::{ArrivalProfile, Gateway, GatewayConfig, GatewayReport};
use dancemoe::trace::recorded::profiles_from_stats;
use dancemoe::trace::{TaskProfile, TraceGenerator};
use dancemoe::util::json::Json;
use dancemoe::util::rng::Rng;

// ---------------------------------------------------------------- digests

fn fnv(h: &mut u64, x: u64) {
    *h ^= x;
    *h = h.wrapping_mul(0x100_0000_01b3);
}

/// Bitwise digest of everything a serve run reports: any drift in RNG
/// draws, event order, booking times or accounting flips it.
fn report_digest(rep: &ServeReport) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    fnv(&mut h, rep.records.len() as u64);
    for r in &rep.records {
        fnv(&mut h, r.id as u64);
        fnv(&mut h, r.server as u64);
        fnv(&mut h, r.tenant as u64);
        for v in [
            r.arrival_s,
            r.done_s,
            r.latency_s,
            r.local_token_invocations,
            r.remote_token_invocations,
        ] {
            fnv(&mut h, v.to_bits());
        }
    }
    fnv(&mut h, rep.net_bytes.to_bits());
    for b in &rep.gpu_busy_s {
        fnv(&mut h, b.to_bits());
    }
    for &(t, n, d) in &rep.migrations {
        fnv(&mut h, t.to_bits());
        fnv(&mut h, n as u64);
        fnv(&mut h, d.to_bits());
    }
    for b in &rep.timeline {
        fnv(&mut h, b.local.to_bits());
        fnv(&mut h, b.remote.to_bits());
        fnv(&mut h, b.completed as u64);
        fnv(&mut h, b.latency_sum.to_bits());
    }
    h
}

fn stats_digest(stats: &ActivationStats) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for s in &stats.servers {
        fnv(&mut h, s.total.to_bits());
        for l in &s.freq {
            for &f in l {
                fnv(&mut h, f.to_bits());
            }
        }
    }
    h
}

// ----------------------------------------------- 1. sampler equivalence

fn assert_same_stream(
    profile: &TaskProfile,
    layer: usize,
    tokens: usize,
    k: usize,
    seed: u64,
) {
    let mut r_ref = Rng::new(seed);
    let mut r_opt = r_ref.clone();
    let a = ref_sample_batch(profile, &mut r_ref, layer, tokens, k);
    let b = profile.sample_batch(&mut r_opt, layer, tokens, k);
    assert_eq!(a, b, "counts diverged (layer {layer}, t {tokens}, k {k})");
    assert_eq!(
        r_ref.next_u64(),
        r_opt.next_u64(),
        "RNG stream position diverged (layer {layer}, t {tokens}, k {k})"
    );
}

#[test]
fn sampler_matches_reference_stream_and_counts() {
    for model in [
        ModelConfig::mixtral_8x7b_sim(),
        ModelConfig::deepseek_v2_lite_sim(),
    ] {
        let k = model.top_k;
        for task in [TaskKind::Arithmetic, TaskKind::MmluPro] {
            let p = TaskProfile::build(task, &model);
            for layer in 0..p.num_layers().min(6) {
                for tokens in [1, 2, 7, 15] {
                    for seed in [1, 42, 977] {
                        assert_same_stream(&p, layer, tokens, k, seed);
                    }
                }
            }
        }
    }
}

#[test]
fn sampler_matches_reference_on_degenerate_recorded_profiles() {
    // recorded profiles can have fewer positive-weight experts than k —
    // the degenerate-fill path must match the reference's zero-sum path
    // exactly (and consume no randomness doing it)
    let rows = vec![
        vec![0.0; 8],                                        // all zero
        {
            let mut r = vec![0.0; 8];
            r[3] = 1.0;                                      // one expert
            r
        },
        {
            let mut r = vec![0.0; 8];
            r[1] = 0.25;
            r[6] = 0.75;                                     // two experts
            r
        },
        vec![0.125; 8],                                      // uniform
    ];
    let p = TaskProfile::from_dist(TaskKind::Arithmetic, rows);
    for layer in 0..4 {
        for k in [1, 2, 4] {
            for tokens in [1, 3, 9] {
                for seed in [5, 333] {
                    assert_same_stream(&p, layer, tokens, k, seed);
                }
            }
        }
    }
}

#[test]
fn fast_sampler_matches_reference() {
    let m = ModelConfig::deepseek_v2_lite_sim();
    let p = TaskProfile::build(TaskKind::Taco, &m);
    for (tokens, k) in [(16, 8), (37, 8), (128, 8), (100, 1)] {
        for seed in [2, 71] {
            let mut r_ref = Rng::new(seed);
            let mut r_opt = r_ref.clone();
            let a = ref_sample_batch_fast(&p, &mut r_ref, 0, tokens, k);
            let b = p.sample_batch_fast(&mut r_opt, 0, tokens, k);
            assert_eq!(a, b, "fast counts diverged (t {tokens}, k {k})");
            assert_eq!(r_ref.next_u64(), r_opt.next_u64());
        }
    }
}

// ------------------------------------------------ 2. engine equivalence

struct EnginePair {
    reference: RefEngine,
    optimized: Engine,
}

impl EnginePair {
    fn new(
        model: &ModelConfig,
        cluster: &ClusterConfig,
        placement: &Placement,
        cfg: EngineConfig,
    ) -> EnginePair {
        EnginePair {
            reference: RefEngine::new(
                model,
                cluster,
                placement.clone(),
                cfg.clone(),
                CostModel::default(),
            ),
            optimized: Engine::new(
                model,
                cluster,
                placement.clone(),
                cfg,
                CostModel::default(),
            ),
        }
    }

    fn assert_identical(&self, label: &str) {
        assert_eq!(
            report_digest(&self.reference.report),
            report_digest(&self.optimized.report),
            "{label}: report bits diverged"
        );
        assert_eq!(
            stats_digest(&self.reference.stats),
            stats_digest(&self.optimized.stats),
            "{label}: activation stats diverged"
        );
        assert_eq!(
            self.reference.events_processed(),
            self.optimized.events_processed(),
            "{label}: event counts diverged"
        );
        assert_eq!(
            self.reference.placement, self.optimized.placement,
            "{label}: placements diverged"
        );
        assert_eq!(
            self.reference
                .measured_remote_penalty_s()
                .map(f64::to_bits),
            self.optimized
                .measured_remote_penalty_s()
                .map(f64::to_bits),
            "{label}: remote-penalty estimator diverged"
        );
        assert_eq!(
            self.reference.redirects, self.optimized.redirects,
            "{label}: offload-LB redirects diverged"
        );
    }
}

#[test]
fn offline_runs_byte_identical_across_modes_models_and_seeds() {
    // mixtral topology, collaborative, two placements, two seeds
    let mut m = ModelConfig::mixtral_8x7b_sim();
    m.num_layers = 4;
    let c = ClusterConfig::edge_testbed_3_for(&m);
    let w = WorkloadConfig::bigbench(10.0);
    let stats = warm_stats(&m, &w);
    for placement in [
        uniform::place(&m, &c),
        PlacementAlgo::DanceMoE.compute(&m, &c, &stats, 1),
    ] {
        for seed in [3u64, 17] {
            let cfg = EngineConfig {
                seed,
                ..EngineConfig::default()
            };
            let mut pair = EnginePair::new(&m, &c, &placement, cfg);
            let trace = TraceGenerator::new(&m, &w, seed).gen_count(30);
            pair.reference.push_trace(&trace);
            pair.optimized.push_trace(&trace);
            pair.reference.run();
            pair.optimized.run();
            pair.assert_identical(&format!("mixtral seed {seed}"));
            assert!(
                pair.optimized.event_slab_high_water()
                    < pair.reference.event_store_len() / 4,
                "slab high-water {} not bounded by in-flight events \
                 (reference grow-only store: {})",
                pair.optimized.event_slab_high_water(),
                pair.reference.event_store_len()
            );
        }
    }

    // deepseek topology (top-8, E=64: multi-word bitsets, fast prefill
    // sampler + exact decode sampler both exercised)
    let mut ds = ModelConfig::deepseek_v2_lite_sim();
    ds.num_layers = 6;
    let dc = ClusterConfig::edge_testbed_3_for(&ds);
    let dw = WorkloadConfig::bigbench(8.0);
    let dstats = warm_stats(&ds, &dw);
    let dp = PlacementAlgo::DanceMoE.compute(&ds, &dc, &dstats, 1);
    let cfg = EngineConfig {
        seed: 9,
        ..EngineConfig::default()
    };
    let mut pair = EnginePair::new(&ds, &dc, &dp, cfg);
    let trace = TraceGenerator::new(&ds, &dw, 9).gen_count(20);
    pair.reference.push_trace(&trace);
    pair.optimized.push_trace(&trace);
    pair.reference.run();
    pair.optimized.run();
    pair.assert_identical("deepseek seed 9");

    // offload mode with load balancing (expert cache + redirect paths)
    let cfg = EngineConfig {
        mode: Mode::Offload { lb: true },
        seed: 5,
        ..EngineConfig::default()
    };
    let mut pair = EnginePair::new(&m, &c, &uniform::place(&m, &c), cfg);
    let trace = TraceGenerator::new(&m, &w, 5).gen_count(25);
    pair.reference.push_trace(&trace);
    pair.optimized.push_trace(&trace);
    pair.reference.run();
    pair.optimized.run();
    pair.assert_identical("offload-lb seed 5");
}

#[test]
fn recorded_profile_replay_byte_identical() {
    // the replay-vs-live harness path: per-server recorded profiles drive
    // the gate instead of the task tables
    let mut m = ModelConfig::mixtral_8x7b_sim();
    m.num_layers = 4;
    let c = ClusterConfig::edge_testbed_3_for(&m);
    let w = WorkloadConfig::bigbench(6.0);
    let placement = uniform::place(&m, &c);
    // capture stats from a live run, then replay them on both engines
    let capture = {
        let cfg = EngineConfig {
            seed: 13,
            ..EngineConfig::default()
        };
        let mut eng = Engine::new(
            &m,
            &c,
            placement.clone(),
            cfg,
            CostModel::default(),
        );
        let trace = TraceGenerator::new(&m, &w, 13).gen_count(20);
        eng.push_trace(&trace);
        eng.run();
        profiles_from_stats(&eng.stats, &m)
    };
    let cfg = EngineConfig {
        seed: 29,
        ..EngineConfig::default()
    };
    let mut pair = EnginePair::new(&m, &c, &placement, cfg);
    pair.reference.set_server_profiles(capture.clone());
    pair.optimized.set_server_profiles(capture);
    let trace = TraceGenerator::new(&m, &w, 29).gen_count(20);
    pair.reference.push_trace(&trace);
    pair.optimized.push_trace(&trace);
    pair.reference.run();
    pair.optimized.run();
    pair.assert_identical("recorded replay seed 29");
}

#[test]
fn online_script_with_migration_and_scaling_byte_identical() {
    // the gateway's co-simulation pattern: staggered injection, segmented
    // run_until, a migration mid-run, then a scale-out / scale-in cycle
    let mut m = ModelConfig::mixtral_8x7b_sim();
    m.num_layers = 4;
    let c = ClusterConfig::edge_testbed_3_for(&m);
    let w = WorkloadConfig::bigbench(4.0);
    let stats = warm_stats(&m, &w);
    let initial = uniform::place(&m, &c);
    let target = PlacementAlgo::DanceMoE.compute(&m, &c, &stats, 1);
    let cfg = EngineConfig {
        seed: 11,
        ..EngineConfig::default()
    };
    let mut pair = EnginePair::new(&m, &c, &initial, cfg);
    let trace = TraceGenerator::new(&m, &w, 11).gen_count(20);
    for (i, r) in trace.requests.iter().enumerate() {
        let at = r.arrival_s + 0.25 * (i % 3) as f64;
        pair.reference.push_request_at(r.clone(), at);
        pair.optimized.push_request_at(r.clone(), at);
    }
    // segmented stepping with bitwise queue-head agreement at every step
    let mut t = 2.0;
    while t < 40.0 {
        let a = pair.reference.run_until(t);
        let b = pair.optimized.run_until(t);
        assert_eq!(
            a.map(f64::to_bits),
            b.map(f64::to_bits),
            "next-event time diverged at t={t}"
        );
        t += 3.0;
    }
    // migration while traffic is in flight
    let at_ref = pair.reference.schedule_migration(target.clone());
    let at_opt = pair.optimized.schedule_migration(target.clone());
    assert_eq!(at_ref.to_bits(), at_opt.to_bits(), "migration apply time");
    pair.reference.run_until(at_ref + 5.0);
    pair.optimized.run_until(at_opt + 5.0);
    assert_eq!(pair.reference.placement, pair.optimized.placement);

    // scale-out a replica, then drain it back out (choose the target from
    // the shared placement state so both engines see the same operation)
    let (l, e) = (0, 0);
    let src = pair.optimized.placement.owners_ref(l, e)[0].0;
    let dst = (0..c.num_servers())
        .find(|&s| !pair.optimized.placement.server_holds(s, l, e));
    if let Some(dst) = dst {
        let out_ref =
            pair.reference.schedule_scale_out(l, e, dst, 0, src).unwrap();
        let out_opt =
            pair.optimized.schedule_scale_out(l, e, dst, 0, src).unwrap();
        assert_eq!(out_ref.to_bits(), out_opt.to_bits(), "scale-out time");
        pair.reference.run_until(out_ref + 1.0);
        pair.optimized.run_until(out_opt + 1.0);
        let in_ref =
            pair.reference.schedule_scale_in(l, e, dst, 0, 10.0).unwrap();
        let in_opt =
            pair.optimized.schedule_scale_in(l, e, dst, 0, 10.0).unwrap();
        assert_eq!(in_ref.to_bits(), in_opt.to_bits(), "scale-in time");
    }
    pair.reference.run();
    pair.optimized.run();
    pair.assert_identical("online script seed 11");
    let ev_ref: Vec<_> = pair
        .reference
        .scale_events
        .iter()
        .map(|e| (e.t_s.to_bits(), e.kind, e.layer, e.expert, e.server, e.gpu, e.applied))
        .collect();
    let ev_opt: Vec<_> = pair
        .optimized
        .scale_events
        .iter()
        .map(|e| (e.t_s.to_bits(), e.kind, e.layer, e.expert, e.server, e.gpu, e.applied))
        .collect();
    assert_eq!(ev_ref, ev_opt, "scale event streams diverged");
}

// ------------------------------------------- 3. serving-stack replay

fn gateway_metrics(rep: &GatewayReport) -> Json {
    Json::from_pairs(vec![
        ("offered", Json::Num(rep.offered as f64)),
        ("admitted", Json::Num(rep.admitted as f64)),
        ("shed", Json::Num(rep.shed as f64)),
        ("spilled", Json::Num(rep.spilled as f64)),
        ("batches", Json::Num(rep.batches as f64)),
        ("bucket_slots", Json::Num(rep.bucket_slots as f64)),
        ("refreshes", Json::Num(rep.refreshes as f64)),
        ("migrations", Json::Num(rep.migrations as f64)),
        ("scale_outs", Json::Num(rep.scale_outs as f64)),
        ("scale_ins", Json::Num(rep.scale_ins as f64)),
        ("p50_s", Json::Num(rep.latency_percentile(0.50))),
        ("p95_s", Json::Num(rep.latency_percentile(0.95))),
        ("p99_s", Json::Num(rep.latency_percentile(0.99))),
        (
            "records_digest",
            Json::Str(format!("{:016x}", report_digest(&rep.serve))),
        ),
    ])
}

fn run_gateway(seed: u64, autoscale: bool) -> GatewayReport {
    let mut m = ModelConfig::mixtral_8x7b_sim();
    m.num_layers = 4;
    let c = ClusterConfig::edge_testbed_3_for(&m);
    let w = WorkloadConfig::bigbench(2.0);
    let profile = if autoscale {
        ArrivalProfile::Bursty {
            factor: 4.0,
            burst_s: 20.0,
            period_s: 60.0,
        }
    } else {
        ArrivalProfile::Poisson
    };
    let coord = CoordinatorConfig {
        interval_s: 30.0,
        seed,
        autoscale: autoscale.then(|| AutoscaleConfig {
            hi_ratio: 1.3,
            lo_ratio: 0.8,
            ..AutoscaleConfig::default()
        }),
        ..CoordinatorConfig::default()
    };
    let mut gw = Gateway::new(
        &m,
        &c,
        &w,
        uniform::place(&m, &c),
        GatewayConfig {
            horizon_s: 150.0,
            profile,
            seed,
            ..GatewayConfig::default()
        },
        coord,
    );
    gw.run()
}

#[test]
fn gateway_runs_serialize_byte_identically_across_reruns() {
    for seed in [7u64, 21] {
        let a = gateway_metrics(&run_gateway(seed, false)).pretty();
        let b = gateway_metrics(&run_gateway(seed, false)).pretty();
        assert_eq!(a, b, "gateway replay diverged at seed {seed}");
    }
}

#[test]
fn autoscale_runs_serialize_byte_identically_across_reruns() {
    for seed in [7u64, 21] {
        let a = gateway_metrics(&run_gateway(seed, true)).pretty();
        let b = gateway_metrics(&run_gateway(seed, true)).pretty();
        assert_eq!(a, b, "autoscale replay diverged at seed {seed}");
    }
}

#[test]
fn tenant_runs_serialize_byte_identically_across_reruns() {
    for seed in [7u64, 21] {
        let (w1, s1, _) = bursty_comparison(seed, 180.0);
        let (w2, s2, _) = bursty_comparison(seed, 180.0);
        assert_eq!(
            bench_file_json(&w1, &s1).pretty(),
            bench_file_json(&w2, &s2).pretty(),
            "tenant replay diverged at seed {seed}"
        );
    }
}
