//! Integration tests for the coordinator's migration loop (Fig. 7 class
//! behaviour): adaptation after workload shifts, Eq.-4 gating end to end.

use dancemoe::config::{ClusterConfig, ModelConfig, WorkloadConfig};
use dancemoe::coordinator::{Coordinator, CoordinatorConfig};
use dancemoe::engine::{warm_stats, CostModel, EngineConfig};
use dancemoe::placement::PlacementAlgo;
use dancemoe::trace::TraceGenerator;

fn small_model() -> ModelConfig {
    let mut m = ModelConfig::mixtral_8x7b_sim();
    m.num_layers = 6;
    m
}

/// Testbed scaled so the 6-layer model is NOT fully replicable on every
/// server (otherwise placement is moot and no migration ever fires).
fn tight_cluster(m: &ModelConfig) -> ClusterConfig {
    let mut c = ClusterConfig::edge_testbed_3_for(m);
    for s in &mut c.servers {
        for g in &mut s.gpus {
            g.mem_bytes /= 5; // ≈ 15 slots/GPU vs 48 experts
        }
    }
    c
}

#[test]
fn workload_shift_triggers_adaptation() {
    let m = small_model();
    let c = tight_cluster(&m);
    let w1 = WorkloadConfig::multidata(6.0);
    let w2 = WorkloadConfig::bigbench(6.0);
    let t1 = TraceGenerator::new(&m, &w1, 31).gen_count(60);
    let t2 = TraceGenerator::new(&m, &w2, 37).gen_count(60);
    let trace = t1.then(t2);
    // start optimal for phase 1
    let initial = {
        let stats = warm_stats(&m, &w1);
        PlacementAlgo::DanceMoE.compute(&m, &c, &stats, 0)
    };
    let run = |migrate: bool| {
        let mut coord = Coordinator::new(
            &m,
            &c,
            CoordinatorConfig {
                interval_s: 120.0,
                migrate,
                ..CoordinatorConfig::default()
            },
        );
        coord.seed_history(&warm_stats(&m, &w1));
        coord.run(
            EngineConfig {
                seed: 31,
                ..EngineConfig::default()
            },
            CostModel::default(),
            initial.clone(),
            &trace,
        )
    };
    let adaptive = run(true);
    let static_ = run(false);
    assert!(!adaptive.migrations.is_empty(), "no migration after shift");
    assert!(static_.migrations.is_empty());
    // local ratio in the post-shift tail
    let tail = |r: &dancemoe::engine::ServeReport| {
        let s = r.local_ratio_series();
        let n = s.len();
        dancemoe::util::stats::mean(&s[n.saturating_sub(n / 3)..])
    };
    let ta = tail(&adaptive);
    let ts = tail(&static_);
    assert!(
        ta > ts,
        "adaptive tail ratio {ta:.3} must beat static {ts:.3}"
    );
}

#[test]
fn migration_cost_visible_in_latency_spike() {
    // Fig. 7b: requests in flight during a migration see extra queueing on
    // the destination GPUs. Compare per-bucket average latency around the
    // first migration against the preceding bucket.
    let m = small_model();
    let c = tight_cluster(&m);
    let w = WorkloadConfig::bigbench(4.0);
    let trace = TraceGenerator::new(&m, &w, 41).gen_count(120);
    let mut coord = Coordinator::new(
        &m,
        &c,
        CoordinatorConfig {
            interval_s: 120.0,
            ..CoordinatorConfig::default()
        },
    );
    // deliberately wrong initial placement so a migration fires
    let initial = PlacementAlgo::Uniform.compute(
        &m,
        &c,
        &warm_stats(&m, &WorkloadConfig::multidata(20.0)),
        0,
    );
    let report = coord.run(
        EngineConfig {
            seed: 41,
            ..EngineConfig::default()
        },
        CostModel::default(),
        initial,
        &trace,
    );
    assert!(
        !report.migrations.is_empty(),
        "expected a migration from the mismatched start"
    );
    let (t_mig, moved, cost) = report.migrations[0];
    assert!(moved > 0);
    assert!(cost > 0.0);
    assert!(t_mig > 0.0);
}

#[test]
fn interval_logs_record_decisions() {
    let m = small_model();
    let c = tight_cluster(&m);
    let w = WorkloadConfig::bigbench(5.0);
    let trace = TraceGenerator::new(&m, &w, 43).gen_count(80);
    let mut coord = Coordinator::new(
        &m,
        &c,
        CoordinatorConfig {
            interval_s: 100.0,
            ..CoordinatorConfig::default()
        },
    );
    let _ = coord.run(
        EngineConfig {
            seed: 43,
            ..EngineConfig::default()
        },
        CostModel::default(),
        PlacementAlgo::Uniform.compute(
            &m,
            &c,
            &dancemoe::moe::ActivationStats::new(&m, 3),
            0,
        ),
        &trace,
    );
    assert!(coord.logs.len() >= 2);
    for log in &coord.logs {
        let d = log.decision.as_ref().expect("migrate enabled");
        // components are internally consistent
        assert!(d.cost_old_s >= 0.0 && d.cost_new_s >= 0.0);
        assert_eq!(d.adopt, d.cost_new_s + d.t_mig_s < d.cost_old_s);
    }
    // the history the scheduler accumulated reflects real observations
    assert!(coord.history.total() > 0.0);
}

#[test]
fn adaptive_never_much_worse_than_static_when_stationary() {
    // With a stationary workload and a good initial placement, enabling
    // migration must not regress latency (Eq. 4 should mostly say "no").
    let m = small_model();
    let c = tight_cluster(&m);
    let w = WorkloadConfig::bigbench(8.0);
    let stats = warm_stats(&m, &w);
    let initial = PlacementAlgo::DanceMoE.compute(&m, &c, &stats, 0);
    let trace = TraceGenerator::new(&m, &w, 47).gen_count(60);
    let run = |migrate: bool| {
        let mut coord = Coordinator::new(
            &m,
            &c,
            CoordinatorConfig {
                interval_s: 150.0,
                migrate,
                ..CoordinatorConfig::default()
            },
        );
        coord.seed_history(&stats);
        coord
            .run(
                EngineConfig {
                    seed: 47,
                    ..EngineConfig::default()
                },
                CostModel::default(),
                initial.clone(),
                &trace,
            )
            .avg_latency()
    };
    let adaptive = run(true);
    let static_ = run(false);
    assert!(
        adaptive <= static_ * 1.15,
        "adaptive {adaptive:.2}s vs static {static_:.2}s"
    );
}

#[test]
fn coordinator_logs_adoptions_to_observability_stream() {
    use dancemoe::util::log;
    let m = small_model();
    let c = tight_cluster(&m);
    let w = WorkloadConfig::bigbench(4.0);
    let trace = TraceGenerator::new(&m, &w, 51).gen_count(80);
    let mut cap = log::capture_at(log::Level::Info);
    let mut coord = Coordinator::new(
        &m,
        &c,
        CoordinatorConfig {
            interval_s: 120.0,
            ..CoordinatorConfig::default()
        },
    );
    let report = coord.run(
        EngineConfig {
            seed: 51,
            ..EngineConfig::default()
        },
        CostModel::default(),
        PlacementAlgo::Uniform.compute(
            &m,
            &c,
            &warm_stats(&m, &WorkloadConfig::multidata(20.0)),
            0,
        ),
        &trace,
    );
    let records = cap.take();
    drop(cap);
    if report.migrations.is_empty() {
        return; // nothing to log in this seeding — other tests cover adoption
    }
    assert!(
        records.iter().any(|r| r.contains("adopting migration")),
        "expected an adoption record, got {records:?}"
    );
}
