//! Byte-identity suite for the sharded region engine: the same scenario
//! run inline (`shards == 1`, the sequential special case) and on 2 or
//! 4 worker shards must produce **identical** output — the full regions
//! report (every counter and float), the merged metrics JSONL stream,
//! the `BENCH_regions.json`-style comparison document, and the chaos
//! report with its per-fault rows and verdicts.
//!
//! The window schedule depends only on shard-invariant inputs (per-
//! region work hints and staged message arrival times), and both
//! executors run the same command dispatcher per region in the same
//! per-region order — so identity is by construction; this suite is the
//! regression lock. Seeds {7, 21} cover two arrival realizations; the
//! chaos case scripts a crash on region 2, which at 2 shards lives
//! alone on the *second* worker (ceiling-division chunks are {0, 1} and
//! {2}), so crash tracking, emergency re-placement, and the rejoin all
//! execute off the first worker thread.

use dancemoe::chaos::{
    self, ChaosScenario, FaultEvent, FaultKind, FaultSchedule,
};
use dancemoe::obs::ObsConfig;
use dancemoe::serve::regions::{
    bench_file_json, ParallelMultiGateway, RegionsScenario,
};

/// Run `scn` on `shards` worker threads with tracing on and fingerprint
/// everything observable: the debug-formatted report (every field, full
/// float precision) plus the merged metrics stream.
fn fingerprint(scn: &RegionsScenario, shards: usize) -> (String, String) {
    let mut m = ParallelMultiGateway::new(scn.build(), shards);
    m.0.enable_obs(ObsConfig::default());
    let rep = m.run();
    (format!("{rep:?}"), m.0.metrics_jsonl())
}

#[test]
fn regions_runs_are_byte_identical_across_shard_counts() {
    for seed in [7u64, 21] {
        let scn = RegionsScenario {
            horizon_s: 180.0,
            seed,
            ..RegionsScenario::default()
        };
        let (seq_report, seq_metrics) = fingerprint(&scn, 1);
        assert!(
            seq_metrics.contains("region_window"),
            "metrics stream must carry exchange rows"
        );
        for shards in [2usize, 4] {
            let (report, metrics) = fingerprint(&scn, shards);
            assert_eq!(
                seq_report, report,
                "seed {seed}, {shards} shards: report diverged"
            );
            assert_eq!(
                seq_metrics, metrics,
                "seed {seed}, {shards} shards: metrics stream diverged"
            );
        }
    }
}

#[test]
fn multi_tenant_runs_are_byte_identical_across_shard_counts() {
    let scn = RegionsScenario {
        horizon_s: 150.0,
        tenants: Some(dancemoe::serve::TenantSet::pair()),
        autoscale: true,
        seed: 21,
        ..RegionsScenario::default()
    };
    let seq = fingerprint(&scn, 1);
    for shards in [2usize, 4] {
        assert_eq!(seq, fingerprint(&scn, shards), "{shards} shards");
    }
}

#[test]
fn bench_document_is_byte_identical_across_shard_counts() {
    // The full BENCH_regions.json-style comparison (spill + isolated +
    // global arms) — the isolated arm exercises the infinite-lookahead
    // path (no cross-region messages ⇒ windows span whole exchange
    // periods), the global arm is shard-free by construction.
    let doc = |shards: usize| {
        let scn = RegionsScenario {
            horizon_s: 180.0,
            seed: 7,
            shards,
            ..RegionsScenario::default()
        };
        let spill = scn.build().run();
        let isolated = RegionsScenario {
            spill: false,
            ..scn.clone()
        }
        .build()
        .run();
        let global = scn.build_global().run();
        bench_file_json(&spill, &isolated, &global).pretty()
    };
    let seq = doc(1);
    assert_eq!(seq, doc(2), "2 shards");
    assert_eq!(seq, doc(4), "4 shards");
}

#[test]
fn chaos_with_crash_on_nonzero_shard_is_byte_identical() {
    let schedule = FaultSchedule::new(vec![
        FaultEvent {
            t_s: 50.0,
            kind: FaultKind::ServerCrash { region: 2, server: 1 },
        },
        FaultEvent {
            t_s: 90.0,
            kind: FaultKind::FlashCrowd { region: 1, tenant: 0, count: 30 },
        },
        FaultEvent {
            t_s: 100.0,
            kind: FaultKind::LinkPartition { src: 2, dst: 0 },
        },
        FaultEvent {
            t_s: 130.0,
            kind: FaultKind::ServerRejoin { region: 2, server: 1 },
        },
        FaultEvent {
            t_s: 150.0,
            kind: FaultKind::LinkRestore { src: 2, dst: 0 },
        },
    ]);
    let run = |shards: usize| {
        let mut scn = ChaosScenario::canonical(21);
        scn.base.horizon_s = 240.0;
        scn.schedule = schedule.clone();
        let rep = scn.run_with_shards(shards);
        assert!(
            rep.conservation_exact && rep.ledger_balanced,
            "{shards} shards: books must stay exact through the faults"
        );
        format!("{:?}\n{}", rep, chaos::bench_file_json(&rep).pretty())
    };
    let seq = run(1);
    for shards in [2usize, 4] {
        assert_eq!(seq, run(shards), "{shards} shards: chaos diverged");
    }
}

#[test]
fn canonical_chaos_is_byte_identical_across_shard_counts() {
    let run = |shards: usize| {
        let rep = ChaosScenario::canonical(7).run_with_shards(shards);
        format!("{:?}\n{}", rep, chaos::bench_file_json(&rep).pretty())
    };
    let seq = run(1);
    assert_eq!(seq, run(2), "2 shards");
    assert_eq!(seq, run(4), "4 shards");
}
