//! Property-based tests on the coordinator's placement invariants
//! (DESIGN.md §7), driven by the in-repo `util::prop` harness.

use dancemoe::config::{ClusterConfig, GpuConfig, ModelConfig, ServerConfig};
use dancemoe::moe::ActivationStats;
use dancemoe::placement::{
    dancemoe_place, entropy_alloc, migration, objective, MemoryLedger,
    Placement, PlacementAlgo,
};
use dancemoe::util::prop::{assert_prop, check, Gen};

/// Random-but-valid (model, cluster, stats) instances.
fn gen_world(g: &mut Gen) -> (ModelConfig, ClusterConfig, ActivationStats) {
    let mut model = ModelConfig::mixtral_8x7b_sim();
    model.num_layers = g.usize_in(1, 6);
    model.num_experts = *g.pick(&[4usize, 8, 16]);
    model.top_k = g.usize_in(1, 2.min(model.num_experts));

    let nsrv = g.usize_in(2, 4);
    let mut servers = Vec::new();
    for s in 0..nsrv {
        let gpus = g.usize_in(1, 2);
        servers.push(ServerConfig {
            name: format!("s{s}"),
            gpus: (0..gpus)
                .map(|_| GpuConfig {
                    // capacity between 40% and 150% of a full expert set
                    // per GPU — spans infeasible and redundant regimes
                    mem_bytes: (model.expert_bytes as f64
                        * model.total_experts() as f64
                        * g.f64_in(0.4, 1.5)
                        / (nsrv as f64))
                        as u64,
                    flops: 100e12 * g.f64_in(0.5, 1.0),
                    pcie_bps: 16e9,
                })
                .collect(),
            host_mem_bytes: 0,
        });
    }
    let cluster = ClusterConfig {
        name: "prop".into(),
        servers,
        bandwidth_bps: 500e6,
        rtt_s: 0.002,
    };
    let mut stats = ActivationStats::new(&model, nsrv);
    for n in 0..nsrv {
        for l in 0..model.num_layers {
            let w = g.weights(model.num_experts);
            for (e, &x) in w.iter().enumerate() {
                if x > 0.0 {
                    stats.record(n, l, e, x * 100.0);
                }
            }
        }
    }
    (model, cluster, stats)
}

#[test]
fn prop_placements_never_violate_memory() {
    check("memory bound", 60, |g| {
        let (model, cluster, stats) = gen_world(g);
        let seed = g.usize_in(0, 1000) as u64;
        for algo in PlacementAlgo::all() {
            let p = algo.compute(&model, &cluster, &stats, seed);
            for s in 0..p.num_servers {
                for gi in 0..p.gpus[s] {
                    assert_prop(
                        p.mem_used(s, gi) <= p.mem_cap[s][gi],
                        &format!("{} overflows s{s}g{gi}", algo.name()),
                    );
                }
            }
        }
    });
}

#[test]
fn prop_feasible_clusters_get_full_coverage() {
    check("coverage", 60, |g| {
        let (model, cluster, stats) = gen_world(g);
        // feasibility at physical (per-GPU) granularity with 2× headroom
        let slots = gpu_slots(&cluster, &model);
        if slots < model.total_experts() * 2 {
            return; // tight instance: best-effort coverage only
        }
        let seed = g.usize_in(0, 1000) as u64;
        for algo in PlacementAlgo::all() {
            let p = algo.compute(&model, &cluster, &stats, seed);
            assert_prop(
                p.missing_experts().is_empty(),
                &format!(
                    "{} missing {} experts with 2x slots",
                    algo.name(),
                    p.missing_experts().len()
                ),
            );
        }
    });
}

/// Capacity in whole experts, floored at the granularity the algorithm
/// actually allocates at (per server for Algorithm 1's count stage).
fn server_slots(cluster: &ClusterConfig, model: &ModelConfig) -> usize {
    cluster
        .servers
        .iter()
        .map(|s| (s.total_mem() / model.expert_bytes) as usize)
        .sum()
}

/// Per-GPU floored capacity (what physical packing can actually hold).
fn gpu_slots(cluster: &ClusterConfig, model: &ModelConfig) -> usize {
    cluster
        .servers
        .iter()
        .flat_map(|s| s.gpus.iter())
        .map(|gc| (gc.mem_bytes / model.expert_bytes) as usize)
        .sum()
}

#[test]
fn prop_algorithm1_totals_cover_each_layer() {
    check("alg1 totals", 80, |g| {
        let (model, cluster, stats) = gen_world(g);
        let counts = entropy_alloc::expert_counts(&model, &cluster, &stats);
        let feasible =
            server_slots(&cluster, &model) >= model.total_experts();
        let shortfall = entropy_alloc::coverage_shortfall(&model, &counts);
        if feasible {
            assert_prop(
                shortfall.iter().all(|&s| s == 0),
                &format!("shortfall {shortfall:?} on feasible instance"),
            );
        }
        // counts never exceed capacity or layer size
        for (n, row) in counts.iter().enumerate() {
            let cap = (cluster.servers[n].total_mem()
                / model.expert_bytes) as usize;
            assert_prop(
                row.iter().sum::<usize>() <= cap,
                "count exceeds capacity",
            );
            assert_prop(
                row.iter().all(|&c| c <= model.num_experts),
                "count exceeds layer size",
            );
        }
    });
}

#[test]
fn prop_dancemoe_remote_mass_not_worse_than_uniform() {
    check("dancemoe vs uniform objective", 40, |g| {
        let (model, cluster, stats) = gen_world(g);
        if gpu_slots(&cluster, &model) < model.total_experts() * 2 {
            return;
        }
        let ours = dancemoe_place(&model, &cluster, &stats);
        let uni = PlacementAlgo::Uniform.compute(&model, &cluster, &stats, 0);
        let mass_ours = objective::remote_mass(&ours, &stats);
        let mass_uni = objective::remote_mass(&uni, &stats);
        assert_prop(
            mass_ours <= mass_uni * 1.001 + 1e-9,
            &format!("ours {mass_ours:.1} > uniform {mass_uni:.1}"),
        );
    });
}

#[test]
fn prop_migration_adoption_is_consistent() {
    check("eq4 consistency", 40, |g| {
        let (model, cluster, stats) = gen_world(g);
        let seed = g.usize_in(0, 100) as u64;
        let old =
            PlacementAlgo::Redundance.compute(&model, &cluster, &stats, seed);
        let new = dancemoe_place(&model, &cluster, &stats);
        let ctx = migration::MigrationCtx::default();
        let d = migration::should_migrate(
            &old, &new, &model, &cluster, &stats, &ctx,
        );
        // adopt implies strict improvement including transfer cost
        if d.adopt {
            assert_prop(
                d.cost_new_s + d.t_mig_s < d.cost_old_s,
                "adopted without net saving",
            );
        } else {
            assert_prop(
                d.cost_new_s + d.t_mig_s >= d.cost_old_s,
                "rejected despite net saving",
            );
        }
        // self-migration is never adopted
        let d2 = migration::should_migrate(
            &old, &old, &model, &cluster, &stats, &ctx,
        );
        assert_prop(!d2.adopt, "self migration adopted");
    });
}

#[test]
fn prop_host_tier_ledger_never_overcommits() {
    // The expert cache's planning protocol — reserve host DRAM, let the
    // prefetch land (stage + release) or abandon it (release), evict by
    // unstaging — can never overshoot a server's host budget, and the
    // tiered free accounting never drifts or underflows.
    check("host ledger", 60, |g| {
        let (model, mut cluster, _stats) = gen_world(g);
        let bytes = model.expert_bytes;
        for s in &mut cluster.servers {
            s.host_mem_bytes = bytes * g.usize_in(0, 5) as u64;
        }
        let mut p = Placement::new(&model, &cluster);
        let mut ledger = MemoryLedger::new(&cluster);
        let nsrv = cluster.num_servers();
        let mut inflight = vec![0usize; nsrv];
        for _ in 0..60 {
            let s = g.usize_in(0, nsrv - 1);
            match g.usize_in(0, 3) {
                // plan a prefetch: the reservation must succeed exactly
                // when the tiered free accounting says the bytes fit
                0 => {
                    let fits = ledger.host_free(&p, s) >= bytes;
                    let got = ledger.try_reserve_host(&p, s, bytes);
                    assert_prop(
                        got == fits,
                        "reserve must match the free accounting",
                    );
                    if got {
                        inflight[s] += 1;
                    }
                }
                // the copy lands: consume the reservation, stage the bits
                // (the reservation guaranteed the room, so staging one
                // not-yet-staged expert must succeed)
                1 if inflight[s] > 0 => {
                    inflight[s] -= 1;
                    ledger.release_host(s, bytes);
                    'find: for l in 0..model.num_layers {
                        for e in 0..model.num_experts {
                            if !p.server_staged(s, l, e) {
                                assert_prop(
                                    p.stage_host(s, l, e).is_ok(),
                                    "a reserved stage must fit",
                                );
                                break 'find;
                            }
                        }
                    }
                }
                // the copy is abandoned: the reservation comes back whole
                2 if inflight[s] > 0 => {
                    inflight[s] -= 1;
                    ledger.release_host(s, bytes);
                }
                // eviction: drop a staged expert (no-op when none staged)
                _ => {
                    if let Some(&(l, e)) = p.staged_experts(s).first() {
                        assert_prop(
                            p.unstage_host(s, l, e).is_ok(),
                            "unstaging a staged expert succeeds",
                        );
                    }
                }
            }
            for n in 0..nsrv {
                assert_prop(
                    p.host_mem_used(n) + ledger.host_reserved(n)
                        <= ledger.host_capacity(n),
                    "host tier over-committed",
                );
                assert_prop(
                    ledger.host_free(&p, n)
                        == ledger.host_capacity(n)
                            - p.host_mem_used(n)
                            - ledger.host_reserved(n),
                    "free accounting drifted",
                );
            }
        }
        // drain everything: the accounting round-trips to pristine
        for s in 0..nsrv {
            while inflight[s] > 0 {
                inflight[s] -= 1;
                ledger.release_host(s, bytes);
            }
            for (l, e) in p.staged_experts(s) {
                p.unstage_host(s, l, e).unwrap();
            }
            assert_prop(p.host_mem_used(s) == 0, "used returns to zero");
            assert_prop(
                ledger.host_free(&p, s) == ledger.host_capacity(s),
                "free returns to capacity",
            );
        }
        assert_prop(
            ledger.total_host_reserved() == 0,
            "reservations all returned",
        );
    });
}

#[test]
fn prop_host_budget_stages_whole_experts_exactly() {
    // A host budget offset by a fraction of an expert still stages only
    // whole experts — exactly floor(budget / expert_bytes) of them — and
    // the used/enumeration accounting agrees with the staged count.
    check("host slots", 60, |g| {
        let (model, mut cluster, _stats) = gen_world(g);
        let slots = g.usize_in(0, 7);
        let frac =
            (model.expert_bytes as f64 * g.f64_in(0.0, 0.99)) as u64;
        for s in &mut cluster.servers {
            s.host_mem_bytes = model.expert_bytes * slots as u64 + frac;
        }
        let mut p = Placement::new(&model, &cluster);
        let total = model.num_layers * model.num_experts;
        let s = g.usize_in(0, cluster.num_servers() - 1);
        let mut staged = 0usize;
        'fill: for l in 0..model.num_layers {
            for e in 0..model.num_experts {
                if p.stage_host(s, l, e).is_err() {
                    break 'fill;
                }
                staged += 1;
            }
        }
        assert_prop(
            staged == slots.min(total),
            "stages exactly the whole-expert slots",
        );
        assert_prop(
            p.host_mem_used(s) == model.expert_bytes * staged as u64,
            "used counts whole experts",
        );
        assert_prop(
            p.staged_experts(s).len() == staged,
            "enumeration matches the staged count",
        );
    });
}

#[test]
fn prop_owner_lookup_consistency() {
    check("owners vs server_has", 40, |g| {
        let (model, cluster, stats) = gen_world(g);
        let p = dancemoe_place(&model, &cluster, &stats);
        for l in 0..model.num_layers {
            for e in 0..model.num_experts {
                let owners = p.owners_ref(l, e);
                for &(s, gi) in owners {
                    assert_prop(p.gpu_has(s, gi, l, e), "owner not on gpu");
                    assert_prop(p.server_has(s, l, e), "owner not on server");
                }
                let n_servers_with: usize = (0..p.num_servers)
                    .filter(|&s| p.server_has(s, l, e))
                    .count();
                assert_prop(
                    n_servers_with <= owners.len(),
                    "server_has without gpu owner",
                );
            }
        }
    });
}
