//! Property/invariant suite for the regionalized serving stack.
//!
//! Locks the spill conservation contract — cross-region spill never
//! duplicates or drops a request: per region and globally, every arrival
//! is exactly one of {admitted, shed, spilled-and-admitted-elsewhere,
//! spilled-and-shed} — plus the acceptance comparison (cross-gateway
//! spill reduces both p95 and shed-rate against the no-spill isolated
//! baseline on the staggered-diurnal scenario) and the deterministic-
//! replay regression for `BENCH_regions.json` (same seed + config ⇒
//! byte-identical metrics across two runs, at two seeds, matching the
//! PR 3/4 pattern). Everything is deterministic and single-threaded per
//! test, so it passes under any `--test-threads` setting.

use dancemoe::serve::regions::{
    bench_file_json, regions_comparison, RegionsReport,
};
use dancemoe::serve::RegionsScenario;

/// Per-region and global conservation: admitted + shed + spilled ==
/// arrivals, with spill resolving to exactly one of admitted-at-peer or
/// shed-at-origin.
fn assert_conservation(report: &RegionsReport) {
    let mut spilled_in_total = 0u64;
    for region in &report.regions {
        let g = &region.gateway;
        // arrivals partition: locally admitted + locally shed + forwarded
        assert_eq!(
            g.offered,
            (g.admitted - region.spilled_in)
                + (g.shed - region.spill_shed)
                + region.spilled_out,
            "{}: offered must partition into local admits, local sheds \
             and forwards",
            region.name
        );
        // the receiving side saw exactly the forwards that were admitted
        assert_eq!(g.forwarded_in, region.spilled_in, "{}", region.name);
        // every admission (local or forwarded) completes exactly once
        assert_eq!(
            g.serve.records.len() as u64,
            g.admitted,
            "{}: admitted requests must complete exactly once",
            region.name
        );
        spilled_in_total += region.spilled_in;
    }
    // globally nothing vanishes and nothing duplicates
    assert_eq!(report.offered, report.admitted + report.shed);
    assert_eq!(
        report.spilled,
        spilled_in_total + report.spill_shed,
        "every forward resolves to a peer admission or an origin shed"
    );
    assert_eq!(report.completed, report.admitted);
}

#[test]
fn spill_conserves_requests_per_region_and_globally() {
    for seed in [3u64, 19] {
        let scenario = RegionsScenario {
            seed,
            horizon_s: 260.0,
            ..RegionsScenario::default()
        };
        let report = scenario.build().run();
        assert!(report.offered > 0);
        assert!(
            report.spilled > 0,
            "seed {seed}: staggered peaks must exercise spill"
        );
        assert_conservation(&report);
    }
}

#[test]
fn isolated_baseline_conserves_without_spill() {
    let scenario = RegionsScenario {
        seed: 3,
        horizon_s: 260.0,
        spill: false,
        ..RegionsScenario::default()
    };
    let report = scenario.build().run();
    assert_eq!(report.spilled, 0);
    assert_eq!(report.spill_shed, 0);
    assert_conservation(&report);
}

#[test]
fn spill_and_isolated_offer_identical_arrivals() {
    // the comparison is apples-to-apples: spill toggling must not change
    // the open-loop arrival streams
    let mk = |spill: bool| {
        RegionsScenario {
            seed: 11,
            horizon_s: 200.0,
            spill,
            ..RegionsScenario::default()
        }
        .build()
        .run()
    };
    let with = mk(true);
    let without = mk(false);
    assert_eq!(with.offered, without.offered);
    for (a, b) in with.regions.iter().zip(&without.regions) {
        assert_eq!(a.gateway.offered, b.gateway.offered, "{}", a.name);
    }
}

#[test]
fn spill_improves_p95_and_shed_rate_vs_isolated() {
    // The acceptance comparison: on the staggered-diurnal 3-region
    // scenario (each region periodically past its own capacity while the
    // cluster-wide load stays constant), cross-gateway spill must reduce
    // both the aggregate p95 and the shed rate against the isolated
    // baseline running identical arrivals.
    let (spill, isolated, _global) = regions_comparison(7, 480.0);
    assert!(isolated.shed > 0, "isolated peaks must shed");
    assert!(spill.spilled > 0, "spill must engage");
    assert!(
        spill.shed_rate() < isolated.shed_rate(),
        "spill must reduce the shed rate ({:.4} vs {:.4})",
        spill.shed_rate(),
        isolated.shed_rate()
    );
    assert!(
        spill.p95_s < isolated.p95_s,
        "spill must reduce aggregate p95 ({:.3}s vs {:.3}s)",
        spill.p95_s,
        isolated.p95_s
    );
    assert!(
        spill.attainment() > isolated.attainment(),
        "spill must improve SLO attainment ({:.3} vs {:.3})",
        spill.attainment(),
        isolated.attainment()
    );
    assert_conservation(&spill);
    assert_conservation(&isolated);
}

#[test]
fn bench_metrics_byte_identical_across_runs() {
    // The deterministic-replay regression (the PR 3/4 pattern): the same
    // seed + config must serialize a byte-identical BENCH_regions metrics
    // document on a re-run — any iteration-order nondeterminism in the
    // multi-gateway loop, the spill mesh or the exchange breaks this
    // immediately. Two seeds, as the acceptance criterion requires.
    for seed in [7u64, 21] {
        let (s1, i1, g1) = regions_comparison(seed, 200.0);
        let (s2, i2, g2) = regions_comparison(seed, 200.0);
        let a = bench_file_json(&s1, &i1, &g1);
        let b = bench_file_json(&s2, &i2, &g2);
        assert_eq!(
            a.pretty(),
            b.pretty(),
            "seed {seed}: metrics must serialize identically"
        );
        let dir = std::env::temp_dir();
        let p1 = dir.join(format!("dancemoe_regions_replay_{seed}_a.json"));
        let p2 = dir.join(format!("dancemoe_regions_replay_{seed}_b.json"));
        a.write_file(&p1).unwrap();
        b.write_file(&p2).unwrap();
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p2).unwrap(),
            "seed {seed}: the written document must be byte-identical"
        );
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
    }
}
