//! Replay-vs-live harness: capture a live gateway run's expert-selection
//! patterns via `trace::recorded`, replay the *same arrival stream* through
//! `World::serve_recorded` under the same placement, and assert the
//! simulator-vs-live gap stays within tolerance — the ROADMAP's
//! "quantify the simulator gap" item, wired as a regression test.

use dancemoe::config::{ClusterConfig, ModelConfig, WorkloadConfig};
use dancemoe::coordinator::CoordinatorConfig;
use dancemoe::engine::{warm_stats, World};
use dancemoe::placement::PlacementAlgo;
use dancemoe::serve::{ArrivalProfile, ArrivalSource, Gateway, GatewayConfig};
use dancemoe::trace::{recorded, Trace};

#[test]
fn replayed_capture_tracks_live_gateway() {
    let mut m = ModelConfig::mixtral_8x7b_sim();
    m.num_layers = 4;
    let c = ClusterConfig::edge_testbed_3_for(&m);
    let w = WorkloadConfig::bigbench(4.0); // light: no shedding, no queueing
    let seed = 47;
    let horizon = 300.0;
    let warm = warm_stats(&m, &w);
    let placement = PlacementAlgo::DanceMoE.compute(&m, &c, &warm, seed);

    // ---- live: gateway co-simulation, static placement, home routing ----
    // (home routing so the live activation stream matches the replay's
    // home-server semantics; tiny batching deadline so queueing structure,
    // not batching wait, is what the comparison sees)
    let mut gw = Gateway::new(
        &m,
        &c,
        &w,
        placement.clone(),
        GatewayConfig {
            horizon_s: horizon,
            locality_routing: false,
            max_wait_s: 0.01,
            queue_cap: 1024,
            max_inflight: 1024,
            seed,
            ..GatewayConfig::default()
        },
        CoordinatorConfig {
            interval_s: 60.0,
            migrate: false,
            seed,
            ..CoordinatorConfig::default()
        },
    );
    let live = gw.run();
    assert_eq!(live.shed, 0, "light load must not shed");
    assert!(live.admitted > 50, "enough traffic to compare");

    // ---- capture: per-server expert-selection patterns from the run -----
    let profiles = recorded::profiles_from_stats(&gw.engine.stats, &m);

    // ---- replay: identical arrival stream through the offline simulator --
    let mut src =
        ArrivalSource::new(&w, ArrivalProfile::Poisson, horizon, seed);
    let mut requests = Vec::new();
    while let Some(r) = src.next_request() {
        requests.push(r);
    }
    let trace = Trace { requests };
    assert_eq!(
        trace.len() as u64,
        live.offered,
        "replay must see the exact live arrival stream"
    );
    let mut world = World::build(&m, &c, &w, seed);
    let replay = world.serve_recorded(&placement, profiles, &trace);
    assert_eq!(replay.records.len() as u64, live.admitted);

    // ---- the gap --------------------------------------------------------
    // locality: same placement + recorded activation patterns must land
    // within a few points of the live run's local-compute ratio
    let live_ratio = live.serve.local_ratio();
    let replay_ratio = replay.local_ratio();
    assert!(
        (live_ratio - replay_ratio).abs() < 0.15,
        "local-ratio gap too wide: live {live_ratio:.3} vs replay \
         {replay_ratio:.3}"
    );
    // latency: the simulator must track the live median within 50 %
    let live_p50 = live.latency_percentile(0.50);
    let replay_p50 = replay.latency_percentile(0.50);
    let gap = (replay_p50 - live_p50).abs() / live_p50.max(1e-9);
    assert!(
        gap < 0.5,
        "simulator-vs-live p50 gap {:.0}% (live {live_p50:.3}s, replay \
         {replay_p50:.3}s)",
        gap * 100.0
    );
}
