//! Cross-language numerics: the Rust PJRT runtime must reproduce the
//! outputs python exported into `artifacts/expected.json` bit-closely.
//!
//! These tests skip (pass trivially with a note) when artifacts have not
//! been built — run `cd python && python -m compile.aot` first for
//! full coverage.

use std::path::PathBuf;

use dancemoe::runtime::Runtime;
use dancemoe::util::json::Json;

fn artifacts_dir() -> PathBuf {
    // tests run from the package root
    Runtime::default_dir()
}

fn load_expected() -> Option<Json> {
    let dir = artifacts_dir();
    if !Runtime::available(&dir) {
        eprintln!("SKIP: no artifacts at {} (python -m compile.aot)", dir.display());
        return None;
    }
    Json::read_file(&dir.join("expected.json")).ok()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

fn replay(name: &str, expected: &Json) -> (Vec<f32>, Vec<f32>) {
    let dir = artifacts_dir();
    let mut rt = Runtime::open(&dir).expect("runtime open");
    let entry = expected.get(name).unwrap_or_else(|| {
        panic!("expected.json lacks vector '{name}'");
    });
    let shapes: Vec<Vec<usize>> = entry
        .req("input_shapes")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.to_usize_vec().unwrap())
        .collect();
    let inputs: Vec<Vec<f32>> = entry
        .req("inputs")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.to_f32_vec().unwrap())
        .collect();
    let want = entry.req("output").unwrap().to_f32_vec().unwrap();
    let refs: Vec<(&[f32], &[usize])> = inputs
        .iter()
        .zip(&shapes)
        .map(|(d, s)| (d.as_slice(), s.as_slice()))
        .collect();
    let got = rt.run_f32(name, &refs).expect("execute");
    (got, want)
}

#[test]
fn expert_kernel_matches_python() {
    let Some(expected) = load_expected() else { return };
    for name in [
        "expert_h64_f128_b1",
        "expert_h64_f128_b8",
        "expert_h64_f128_b32",
    ] {
        let (got, want) = replay(name, &expected);
        assert_eq!(got.len(), want.len(), "{name}");
        let d = max_abs_diff(&got, &want);
        assert!(d < 1e-5, "{name}: max abs diff {d}");
    }
}

#[test]
fn gate_matches_python_both_expert_counts() {
    let Some(expected) = load_expected() else { return };
    for name in ["gate_h64_e8_b8", "gate_h64_e64_b8"] {
        let (got, want) = replay(name, &expected);
        let d = max_abs_diff(&got, &want);
        assert!(d < 1e-6, "{name}: max abs diff {d}");
        // rows are probability distributions
        let e = if name.contains("e64") { 64 } else { 8 };
        for row in got.chunks(e) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "{name}: row sums to {s}");
        }
    }
}

#[test]
fn nonmoe_matches_python() {
    let Some(expected) = load_expected() else { return };
    let (got, want) = replay("nonmoe_h64_b8", &expected);
    let d = max_abs_diff(&got, &want);
    assert!(d < 1e-5, "nonmoe: max abs diff {d}");
}

#[test]
fn dense_moe_layer_oracle_matches_python() {
    let Some(expected) = load_expected() else { return };
    let (got, want) = replay("moe_layer_dense_h64_f128_e8_b8", &expected);
    let d = max_abs_diff(&got, &want);
    assert!(d < 1e-4, "dense oracle: max abs diff {d}");
}

#[test]
fn executable_cache_reuses_compilations() {
    let dir = artifacts_dir();
    if !Runtime::available(&dir) {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let mut rt = Runtime::open(&dir).unwrap();
    assert_eq!(rt.cached(), 0);
    rt.load("gate_h64_e8_b8").unwrap();
    rt.load("gate_h64_e8_b8").unwrap();
    assert_eq!(rt.cached(), 1);
    rt.load("gate_h64_e8_b1").unwrap();
    assert_eq!(rt.cached(), 2);
}

#[test]
fn shape_mismatch_is_rejected() {
    let dir = artifacts_dir();
    if !Runtime::available(&dir) {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let mut rt = Runtime::open(&dir).unwrap();
    let bad = vec![0.0f32; 8 * 64];
    // wrong second input shape
    let err = rt.run_f32(
        "gate_h64_e8_b8",
        &[(&bad, &[8, 64]), (&bad[..64], &[8, 8])],
    );
    assert!(err.is_err());
}
