//! Property/invariant suite for the multi-tenant serve stack.
//!
//! Locks the three contracts of the weighted-deficit admission policy —
//! work conservation, weight-proportional dequeue shares over long
//! backlogged horizons, and starvation-freedom of a weight-1 tenant under
//! a hostile heavy tenant — plus the deterministic-replay regression for
//! `BENCH_tenants.json` (same seed + config ⇒ byte-identical metrics
//! across two runs, guarding the event loop against nondeterministic
//! iteration order) and the acceptance comparison: on the bursty preset
//! the weighted gateway improves the constrained tenant's p95 over the
//! shared-queue baseline. Everything here is deterministic and
//! single-threaded per test, so it passes under any `--test-threads`
//! setting (both CI matrix configurations).

use dancemoe::config::TaskKind;
use dancemoe::serve::tenant::{bench_file_json, bursty_comparison};
use dancemoe::serve::AdmissionController;
use dancemoe::trace::Request;
use dancemoe::util::prop;

fn treq(id: usize, tenant: usize) -> Request {
    Request {
        id,
        server: 0,
        arrival_s: id as f64,
        prompt_tokens: 16,
        output_tokens: 4,
        task: TaskKind::Arithmetic,
        tenant,
    }
}

#[test]
fn prop_work_conservation() {
    // No server idles while any tenant queue holds work: every pop
    // returns exactly min(n, queued-at-server), whatever mix of tenants,
    // weights and interleavings produced the backlog.
    prop::check("pop returns min(n, queued)", 120, |g| {
        let nt = g.usize_in(1, 4);
        let caps: Vec<usize> = (0..nt).map(|_| g.usize_in(1, 24)).collect();
        let weights: Vec<u64> =
            (0..nt).map(|_| g.usize_in(1, 8) as u64).collect();
        let mut adm = AdmissionController::with_tenants(1, &caps, &weights);
        let mut id = 0;
        let mut queued = 0usize;
        for _ in 0..g.usize_in(1, 120) {
            if g.bool() {
                let t = g.usize_in(0, nt - 1);
                if adm.offer(0, treq(id, t), 0.0) {
                    queued += 1;
                }
                id += 1;
            } else {
                let n = g.usize_in(0, 12);
                let popped = adm.pop(0, n);
                prop::assert_prop(
                    popped.len() == n.min(queued),
                    "work conservation: pop must drain min(n, queued)",
                );
                queued -= popped.len();
            }
            prop::assert_prop(
                adm.depth(0) == queued,
                "depth accounting must track offers and pops",
            );
        }
    });
}

#[test]
fn prop_weight_proportional_shares() {
    // With every tenant queue kept backlogged, long-horizon dequeue
    // shares converge to weight / Σ weights regardless of pop sizing.
    prop::check("backlogged shares follow weights", 40, |g| {
        let nt = g.usize_in(2, 3);
        let weights: Vec<u64> =
            (0..nt).map(|_| g.usize_in(1, 6) as u64).collect();
        let caps = vec![64usize; nt];
        let mut adm = AdmissionController::with_tenants(1, &caps, &weights);
        let mut id = 0;
        let mut served = vec![0u64; nt];
        for _ in 0..200 {
            for t in 0..nt {
                while adm.tenant_depth(0, t) < 32 {
                    assert!(adm.offer(0, treq(id, t), 0.0));
                    id += 1;
                }
            }
            for q in adm.pop(0, g.usize_in(1, 8)) {
                served[q.req.tenant] += 1;
            }
        }
        let total: u64 = served.iter().sum();
        let total_w: u64 = weights.iter().sum();
        for t in 0..nt {
            let share = served[t] as f64 / total as f64;
            let expect = weights[t] as f64 / total_w as f64;
            prop::assert_prop(
                (share - expect).abs() < 0.05,
                "long-horizon share must track the weight proportion",
            );
        }
    });
}

#[test]
fn prop_hostile_heavy_tenant_cannot_starve_weight_one() {
    // A heavy tenant that refills its queue to the bound before every
    // dequeue can delay a weight-1 tenant by at most its own quantum:
    // the light tenant is served at least once per DRR cycle.
    prop::check("weight-1 tenant served every cycle", 40, |g| {
        let heavy_w = g.usize_in(1, 16) as u64;
        let mut adm =
            AdmissionController::with_tenants(1, &[64, 64], &[heavy_w, 1]);
        let mut id = 0;
        for _ in 0..16 {
            assert!(adm.offer(0, treq(id, 1), 0.0));
            id += 1;
        }
        let mut light_served = 0u64;
        let mut since_light = 0u64;
        let mut guard = 0u64;
        while light_served < 16 {
            // hostile: the heavy tenant is always backlogged to its bound
            while adm.tenant_depth(0, 0) < 64 {
                assert!(adm.offer(0, treq(id, 0), 0.0));
                id += 1;
            }
            for q in adm.pop(0, 1) {
                if q.req.tenant == 1 {
                    light_served += 1;
                    since_light = 0;
                } else {
                    since_light += 1;
                    prop::assert_prop(
                        since_light <= heavy_w,
                        "heavy tenant ran past its quantum — starvation",
                    );
                }
            }
            guard += 1;
            prop::assert_prop(
                guard <= 16 * (heavy_w + 2),
                "light tenant not served within its cycle bound",
            );
        }
    });
}

#[test]
fn bench_metrics_byte_identical_across_runs() {
    // The deterministic-replay regression: same seed + config must yield
    // a byte-identical BENCH_tenants.json metrics object on a re-run —
    // any HashMap-ordered iteration sneaking into the event loop or the
    // report path breaks this immediately.
    let (w1, s1, _) = bursty_comparison(11, 240.0);
    let (w2, s2, _) = bursty_comparison(11, 240.0);
    let m1 = bench_file_json(&w1, &s1);
    let m2 = bench_file_json(&w2, &s2);
    assert_eq!(
        m1.pretty(),
        m2.pretty(),
        "metrics must serialize identically for identical (seed, config)"
    );
    let dir = std::env::temp_dir();
    let p1 = dir.join("dancemoe_tenants_replay_a.json");
    let p2 = dir.join("dancemoe_tenants_replay_b.json");
    m1.write_file(&p1).unwrap();
    m2.write_file(&p2).unwrap();
    assert_eq!(
        std::fs::read(&p1).unwrap(),
        std::fs::read(&p2).unwrap(),
        "the written BENCH_tenants document must be byte-identical"
    );
    let _ = std::fs::remove_file(&p1);
    let _ = std::fs::remove_file(&p2);

    // ---- acceptance comparison on the same runs -------------------------
    // Weighted admission must repair the constrained (interactive)
    // tenant's p95 relative to the shared-queue baseline under the batch
    // tenant's bursts...
    let (wi, si) = (&w1.tenants[0], &s1.tenants[0]);
    assert!(wi.completed > 0 && si.completed > 0);
    assert!(
        wi.p95_s < si.p95_s,
        "weighted admission must improve the constrained tenant's p95 \
         (weighted {:.3}s vs shared {:.3}s)",
        wi.p95_s,
        si.p95_s
    );
    // ...while the heavy tenant still makes progress (no starvation end
    // to end), and per-tenant accounting stays conservation-clean.
    assert!(w1.tenants[1].completed > 0, "batch tenant starved");
    for rep in [&w1, &s1] {
        let off: u64 = rep.tenants.iter().map(|t| t.offered).sum();
        assert_eq!(off, rep.offered);
        for t in &rep.tenants {
            assert_eq!(t.offered, t.admitted + t.shed);
        }
    }
}
