//! End-to-end properties of the tracing layer: byte-determinism of the
//! exported artifacts, exact latency decomposition (gateway and
//! multi-region runs, spill stage included), result-neutrality, recorder
//! bounds, and flight-recorder triggering.

use dancemoe::config::{ClusterConfig, ModelConfig, WorkloadConfig};
use dancemoe::coordinator::CoordinatorConfig;
use dancemoe::obs::ObsConfig;
use dancemoe::placement::uniform;
use dancemoe::serve::{
    Gateway, GatewayConfig, RegionsScenario, TenantSet,
};
use dancemoe::util::json::Json;

fn gateway(gcfg: GatewayConfig) -> Gateway {
    let mut m = ModelConfig::mixtral_8x7b_sim();
    m.num_layers = 4;
    let c = ClusterConfig::edge_testbed_3_for(&m);
    let w = WorkloadConfig::bigbench(1.0);
    let initial = uniform::place(&m, &c);
    Gateway::new(
        &m,
        &c,
        &w,
        initial,
        gcfg,
        CoordinatorConfig {
            interval_s: 30.0,
            ..CoordinatorConfig::default()
        },
    )
}

/// One traced gateway run's exported artifacts.
fn run_traced(seed: u64) -> (String, String) {
    let mut gw = gateway(GatewayConfig {
        horizon_s: 120.0,
        seed,
        ..GatewayConfig::default()
    });
    gw.enable_obs(ObsConfig::default());
    let _ = gw.run();
    (gw.trace_json().to_string(), gw.metrics_jsonl())
}

#[test]
fn same_seed_artifacts_are_byte_identical() {
    let (t1, m1) = run_traced(11);
    let (t2, m2) = run_traced(11);
    assert_eq!(t1, t2, "same seed ⇒ byte-identical Chrome trace");
    assert_eq!(m1, m2, "same seed ⇒ byte-identical metrics JSONL");
    let (t3, m3) = run_traced(12);
    assert_ne!(t1, t3, "a different seed must change the trace");
    assert_ne!(m1, m3, "a different seed must change the metrics");
}

#[test]
fn chrome_trace_document_is_wellformed() {
    let (trace, metrics) = run_traced(11);
    let j = Json::parse(&trace).expect("trace must parse as JSON");
    let evs = match j.get("traceEvents") {
        Some(Json::Arr(v)) => v,
        other => panic!("traceEvents must be an array, got {other:?}"),
    };
    assert!(!evs.is_empty(), "a served run must emit events");
    for e in evs {
        assert!(e.get("ph").is_some(), "every event has a phase");
        assert!(e.get("pid").is_some(), "every event has a process");
        assert!(e.get("name").is_some(), "every event has a name");
    }
    // every metrics row is one valid JSON object with t_s and kind
    assert!(metrics.lines().count() >= 3);
    for line in metrics.lines() {
        let row = Json::parse(line).expect("each JSONL row parses");
        assert!(row.get("t_s").and_then(|v| v.as_f64()).is_some());
        assert!(row.get("kind").is_some());
    }
}

#[test]
fn regions_trace_covers_spill_and_decomposes_exactly() {
    // the canonical staggered-diurnal scenario: forwards happen, so the
    // decomposition must book non-zero spill time somewhere — and every
    // traced request must still decompose to its exact latency
    let scenario = RegionsScenario {
        horizon_s: 200.0,
        autoscale: true,
        seed: 5,
        ..RegionsScenario::default()
    };
    let mut multi = scenario.build();
    multi.enable_obs(ObsConfig::default());
    let report = multi.run();
    assert!(report.spilled > 0, "scenario must spill");
    let mut checked = 0usize;
    let mut spill_total = 0.0;
    for gw in &multi.gateways {
        for rec in &gw.engine.obs.completed {
            let total = rec.stages.total();
            assert!(
                (total - rec.latency_s).abs()
                    <= 1e-6 * rec.latency_s.max(1e-9),
                "stage sum {total} != latency {}",
                rec.latency_s
            );
            spill_total += rec.stages.spill_s;
            checked += 1;
        }
    }
    assert!(checked > 0, "completions must be traced");
    assert!(
        spill_total > 0.0,
        "forwarded completions must book inter-region transfer as spill"
    );
    for region in &report.regions {
        let d = region.gateway.decomp.as_ref().expect("per-region decomp");
        assert!(d.comms_share + d.compute_share <= 1.0 + 1e-9);
    }
}

#[test]
fn regions_artifacts_are_deterministic() {
    let run = || {
        let mut multi = RegionsScenario {
            horizon_s: 150.0,
            seed: 7,
            ..RegionsScenario::default()
        }
        .build();
        multi.enable_obs(ObsConfig::default());
        let _ = multi.run();
        (multi.trace_json().to_string(), multi.metrics_jsonl())
    };
    let (t1, m1) = run();
    let (t2, m2) = run();
    assert_eq!(t1, t2);
    assert_eq!(m1, m2);
    // region-tagged rows, merged in clock order
    let mut last = f64::NEG_INFINITY;
    let mut regions_seen = std::collections::BTreeSet::new();
    for line in m1.lines() {
        let row = Json::parse(line).unwrap();
        let t = row.get("t_s").and_then(|v| v.as_f64()).unwrap();
        assert!(t >= last, "rows must be in virtual-clock order");
        last = t;
        if let Some(Json::Str(r)) = row.get("region") {
            regions_seen.insert(r.clone());
        }
    }
    assert_eq!(regions_seen.len(), 3, "every region contributes rows");
}

#[test]
fn regions_tracing_is_result_neutral() {
    let run = |trace: bool| {
        let mut multi = RegionsScenario {
            horizon_s: 150.0,
            tenants: Some(TenantSet::pair()),
            seed: 13,
            ..RegionsScenario::default()
        }
        .build();
        if trace {
            multi.enable_obs(ObsConfig::default());
        }
        multi.run()
    };
    let plain = run(false);
    let traced = run(true);
    assert_eq!(plain.offered, traced.offered);
    assert_eq!(plain.admitted, traced.admitted);
    assert_eq!(plain.shed, traced.shed);
    assert_eq!(plain.spilled, traced.spilled);
    assert_eq!(plain.completed, traced.completed);
    assert_eq!(plain.p95_s.to_bits(), traced.p95_s.to_bits());
    assert_eq!(plain.p99_s.to_bits(), traced.p99_s.to_bits());
}

#[test]
fn event_store_bound_holds_end_to_end() {
    let mut gw = gateway(GatewayConfig {
        horizon_s: 120.0,
        seed: 17,
        ..GatewayConfig::default()
    });
    gw.enable_obs(ObsConfig {
        max_events: 64,
        ..ObsConfig::default()
    });
    let _ = gw.run();
    let obs = &gw.engine.obs;
    assert!(obs.events.len() <= 64, "span store must stay bounded");
    assert!(obs.dropped > 0, "a 2-minute run overflows 64 slots");
}

#[test]
fn slo_breach_dumps_the_flight_ring() {
    // a sub-millisecond SLO: every interval window with completions
    // breaches, so dumps fire and cap at the configured bound
    let mut gw = gateway(GatewayConfig {
        horizon_s: 120.0,
        slo_s: 1e-3,
        seed: 9,
        ..GatewayConfig::default()
    });
    gw.enable_obs(ObsConfig::default());
    let _ = gw.run();
    let obs = &gw.engine.obs;
    assert!(!obs.dumps.is_empty(), "sub-millisecond SLO must breach");
    assert!(obs.dumps.len() <= obs.cfg.max_flight_dumps);
    for d in &obs.dumps {
        assert_eq!(d.reason, "slo_breach");
        assert!(!d.events.is_empty(), "the ring had recent spans");
        for w in d.events.windows(2) {
            assert!(w[0].t_s <= w[1].t_s, "ring snapshots chronological");
        }
    }
    let flight = gw.flight_json().to_string();
    assert!(flight.contains("slo_breach"));
}
